//! Checkpoint-corruption matrix (the "fail at load, never at predict"
//! contract): every way a checkpoint directory can be damaged — truncated
//! sidecar, flipped byte, missing manifest, a crash that left only the
//! half-written `.tmp` staging directory — is rejected loudly by
//! `load`/`peek`, and a checkpoint that *does* load serves bitwise-correct
//! predictions. Training-state records get the same treatment.

use exactgp::config::{Backend, Config};
use exactgp::coordinator;
use exactgp::data::synthetic::Scale;
use exactgp::faults::FaultPlan;
use exactgp::gp::exact::{ExactGp, Recipe, StepLog};
use exactgp::metrics::AccountingSnapshot;
use exactgp::opt::AdamState;
use exactgp::runtime::checkpoint::{self, TrainState};
use exactgp::util::rng::{Rng, RngState};
use std::path::{Path, PathBuf};

fn base_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.backend = Backend::Native;
    cfg.scale = Scale { train_cap: 128 };
    cfg.workers = 1;
    cfg.pretrain_subset = 64;
    cfg.pretrain_lbfgs_steps = 2;
    cfg.pretrain_adam_steps = 2;
    cfg.finetune_adam_steps = 2;
    cfg.precond_rank = 16;
    cfg.variance_rank = 24;
    cfg
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("exactgp_cc_{tag}_{}", std::process::id()))
}

fn trained_model(cfg: &Config, name: &str) -> (ExactGp, exactgp::data::Dataset) {
    let ds = coordinator::load_dataset(cfg, name, 0).unwrap();
    let (pool, spec) = coordinator::make_pool(cfg, ds.d).unwrap();
    let mut rng = Rng::new(11, 0);
    let mut gp = ExactGp::new(cfg, cfg.kernel, &ds, pool, spec);
    gp.train(Recipe::paper_default(cfg), &mut rng).unwrap();
    gp.precompute(&mut rng).unwrap();
    (gp, ds)
}

fn load_err(dir: &Path) -> String {
    format!("{:#}", checkpoint::load(dir).unwrap_err())
}

/// Every sidecar, two damage modes each: truncation must fail the length
/// check, a flipped byte must fail the checksum — always at load, with
/// the original bytes restored (and load re-verified) between cases.
#[test]
fn every_sidecar_rejects_truncation_and_bitflips_at_load() {
    let cfg = base_cfg();
    let (gp, ds) = trained_model(&cfg, "bike");
    let dir = tmp_dir("matrix");
    let _ = std::fs::remove_dir_all(&dir);
    gp.save(&dir, &ds).unwrap();

    let mut sidecars: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|e| e == "bin"))
        .collect();
    sidecars.sort();
    assert!(sidecars.len() >= 5, "expected the full sidecar set, got {sidecars:?}");

    for file in &sidecars {
        let original = std::fs::read(file).unwrap();

        // Truncated: the manifest's element count no longer matches.
        std::fs::write(file, &original[..original.len() / 2]).unwrap();
        let err = load_err(&dir);
        assert!(
            err.contains("corrupt checkpoint") && err.contains("holds"),
            "truncated {file:?}: {err}"
        );

        // One flipped byte: the FNV checksum catches it.
        let mut bytes = original.clone();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(file, &bytes).unwrap();
        let err = load_err(&dir);
        assert!(err.contains("checksum"), "bitflipped {file:?}: {err}");

        // Deleted: a clear "missing array" error, not a panic.
        std::fs::remove_file(file).unwrap();
        let err = load_err(&dir);
        assert!(err.contains("reading checkpoint array"), "deleted {file:?}: {err}");

        std::fs::write(file, &original).unwrap();
        checkpoint::load(&dir).unwrap_or_else(|e| {
            panic!("restored {file:?} but load still fails: {e:#}")
        });
    }

    // A checkpoint that loads serves bitwise-correct predictions — the
    // corruption checks above are what lets predict trust its inputs.
    let want = gp.predict(&ds.test_x).unwrap();
    let (gp2, ds2) = coordinator::load_model(&cfg, &dir).unwrap();
    let got = gp2.predict(&ds2.test_x).unwrap();
    for i in 0..want.mean.len() {
        assert_eq!(got.mean[i].to_bits(), want.mean[i].to_bits());
        assert_eq!(got.var[i].to_bits(), want.var[i].to_bits());
    }

    let _ = std::fs::remove_dir_all(&dir);
}

/// Manifest damage: deleting it makes the directory "not a checkpoint";
/// corrupting its JSON is reported as such. `peek` (the registry's cheap
/// scan) applies the same checks.
#[test]
fn manifest_damage_fails_load_and_peek() {
    let cfg = base_cfg();
    let (gp, ds) = trained_model(&cfg, "bike");
    let dir = tmp_dir("manifest");
    let _ = std::fs::remove_dir_all(&dir);
    gp.save(&dir, &ds).unwrap();

    let manifest = dir.join("checkpoint.json");
    let original = std::fs::read(&manifest).unwrap();

    // Garbage JSON.
    std::fs::write(&manifest, b"{ not json").unwrap();
    assert!(load_err(&dir).contains("corrupt checkpoint manifest"));
    let perr = format!("{:#}", checkpoint::peek(&dir).unwrap_err());
    assert!(perr.contains("corrupt checkpoint manifest"), "{perr}");

    // Missing manifest: the directory is simply not a checkpoint.
    std::fs::remove_file(&manifest).unwrap();
    assert!(!checkpoint::exists(&dir));
    assert!(load_err(&dir).contains("no checkpoint at"));
    let perr = format!("{:#}", checkpoint::peek(&dir).unwrap_err());
    assert!(perr.contains("no checkpoint at"), "{perr}");

    std::fs::write(&manifest, &original).unwrap();
    checkpoint::load(&dir).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A crash mid-save (injected at the manifest write, after all sidecars
/// landed in staging) must leave nothing visible: the target directory
/// does not exist, only a `<dir>.tmp` staging leftover — which the next
/// load attempt garbage-collects — and a retry produces a good checkpoint.
#[test]
fn crash_during_save_leaves_no_visible_checkpoint() {
    let cfg = base_cfg();
    let (gp, ds) = trained_model(&cfg, "elevators");
    let dir = tmp_dir("halfrename");
    let staged = PathBuf::from(format!("{}.tmp", dir.display()));
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&staged);

    let plan = FaultPlan::parse("ckpt.partial:1").unwrap();
    let err = format!("{:#}", gp.save_with(&dir, &ds, &plan).unwrap_err());
    assert!(err.contains("ckpt.partial"), "{err}");

    // The invariant: a visible checkpoint directory is always complete.
    assert!(!dir.exists(), "crash mid-save published a partial checkpoint");
    assert!(!checkpoint::exists(&dir));
    assert!(staged.is_dir(), "the staging leftover should still be on disk");
    assert!(load_err(&dir).contains("no checkpoint at"));
    assert!(!staged.exists(), "load must garbage-collect stale staging dirs");

    // Same story when the simulated disk fills mid-sidecar.
    let plan = FaultPlan::parse("ckpt.enospc:2").unwrap();
    let err = format!("{:#}", gp.save_with(&dir, &ds, &plan).unwrap_err());
    assert!(err.contains("no space left on device"), "{err}");
    assert!(!dir.exists());

    // The retry (no armed faults) succeeds where the crashed save failed.
    gp.save(&dir, &ds).unwrap();
    checkpoint::load(&dir).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

fn toy_train_state() -> TrainState {
    TrainState {
        kernel: Config::default().kernel,
        config_fingerprint: 0xabcd,
        dataset_name: "toy".into(),
        d: 3,
        n_train: 16,
        total_steps: 4,
        pretrain: true,
        step: 1,
        n_ls: 3,
        params: vec![0.1, 0.2, 0.3, 0.4, 0.5],
        adam: AdamState { m: vec![0.0; 5], v: vec![0.0; 5], t: 1 },
        rng: RngState { state: 7, inc: 13, spare_normal: Some(0.25) },
        step_log: vec![StepLog { step: 0, nll: 1.5, cg_iters: 9, seconds: 0.1 }],
        pretrain_seconds: 0.0,
        train_seconds: 0.2,
        acct: AccountingSnapshot::default(),
    }
}

/// Training-state records refuse corruption just as loudly: a damaged
/// record must never silently restart training from wrong state.
#[test]
fn corrupt_train_state_records_fail_loudly() {
    let ckpt_dir = tmp_dir("trainstate");
    let root = checkpoint::train_state_root(&ckpt_dir);
    let _ = std::fs::remove_dir_all(&root);

    let st = toy_train_state();
    checkpoint::save_train_state(&ckpt_dir, &st, &FaultPlan::default()).unwrap();
    assert!(checkpoint::train_state_exists(&ckpt_dir));
    let record = root.join("step-000001");
    assert!(record.is_dir());

    // Round-trips bit-for-bit first.
    let back = checkpoint::load_train_state(&ckpt_dir).unwrap();
    assert_eq!(back.params, st.params);
    assert_eq!(back.rng, st.rng);
    assert_eq!(back.adam, st.adam);

    let params = record.join("params.bin");
    let original = std::fs::read(&params).unwrap();

    // Truncated sidecar.
    std::fs::write(&params, &original[..8]).unwrap();
    let err = format!("{:#}", checkpoint::load_train_state(&ckpt_dir).unwrap_err());
    assert!(err.contains("holds"), "{err}");

    // Flipped byte.
    let mut bytes = original.clone();
    bytes[3] ^= 0x80;
    std::fs::write(&params, &bytes).unwrap();
    let err = format!("{:#}", checkpoint::load_train_state(&ckpt_dir).unwrap_err());
    assert!(err.contains("checksum"), "{err}");
    std::fs::write(&params, &original).unwrap();

    // Missing record manifest: loud, no silent fallback to nothing.
    std::fs::remove_file(record.join("train_state.json")).unwrap();
    let err = format!("{:#}", checkpoint::load_train_state(&ckpt_dir).unwrap_err());
    assert!(err.contains("no training-state record at"), "{err}");

    // A stale staging dir next to the records is ignored and collected.
    let _ = std::fs::remove_dir_all(&root);
    checkpoint::save_train_state(&ckpt_dir, &st, &FaultPlan::default()).unwrap();
    let junk = root.join("step-000009.tmp");
    std::fs::create_dir_all(&junk).unwrap();
    let back = checkpoint::load_train_state(&ckpt_dir).unwrap();
    assert_eq!(back.step, 1, "a .tmp leftover must never win over a real record");
    assert!(!junk.exists(), "stale staging dirs are garbage-collected");

    let _ = std::fs::remove_dir_all(&root);
}

/// The append-delta fault seams, end to end through the model API: a
/// crash before the publish rename leaves only staging that the next
/// load garbage-collects; a torn published tail is garbage-collected
/// too; a flipped byte inside a delta sidecar fails the checksum at
/// load; and the retry that finally lands replays into the appended
/// model bitwise.
#[test]
fn append_delta_crashes_recover_and_replay_bitwise() {
    let cfg = base_cfg();
    let (mut gp, mut ds) = trained_model(&cfg, "bike");
    let dir = tmp_dir("appendfault");
    let _ = std::fs::remove_dir_all(&dir);
    gp.save(&dir, &ds).unwrap();
    let n_before = ds.n_train();

    // Fold five fresh points in (the cold, parity-grade path) and grow
    // the dataset to match — save_append requires the post-append set.
    let k = 5;
    let new_x = ds.test_x[..k * ds.d].to_vec();
    let new_y = ds.test_y[..k].to_vec();
    gp.fold_observations(&new_x, &new_y).unwrap();
    ds.train_x.extend_from_slice(&new_x);
    ds.train_y.extend_from_slice(&new_y);

    // Crash window 1: staged but never published. The record must stay
    // invisible — the next load serves the base model and sweeps the
    // staging directory.
    let plan = FaultPlan::parse("append.crash:1").unwrap();
    let err = format!("{:#}", gp.save_append(&dir, &ds, k, &plan).unwrap_err());
    assert!(err.contains("append.crash"), "{err}");
    assert!(
        dir.join("append-000001.tmp").is_dir(),
        "the crash window leaves exactly the staging dir"
    );
    assert!(!dir.join("append-000001").exists());
    let ck = checkpoint::load(&dir).unwrap();
    assert_eq!(ck.dataset.n_train(), n_before, "unpublished delta must stay invisible");
    assert!(
        !dir.join("append-000001.tmp").exists(),
        "load must garbage-collect append staging leftovers"
    );

    // Crash window 2: published, but with a manifest that stops
    // mid-byte. As the last record in the chain it is the footprint of a
    // mid-publish crash, so load garbage-collects it — that append
    // simply didn't happen.
    let plan = FaultPlan::parse("append.delta-torn:1").unwrap();
    let err = format!("{:#}", gp.save_append(&dir, &ds, k, &plan).unwrap_err());
    assert!(err.contains("append.delta-torn"), "{err}");
    assert!(dir.join("append-000001").is_dir(), "the torn record was published");
    let ck = checkpoint::load(&dir).unwrap();
    assert_eq!(ck.dataset.n_train(), n_before);
    assert!(!dir.join("append-000001").exists(), "torn tail must be garbage-collected");

    // The retry lands (the chain restarts at 1 — both failed attempts
    // were swept, never numbered).
    let seq = gp.save_append(&dir, &ds, k, &FaultPlan::default()).unwrap();
    assert_eq!(seq, 1);

    // A flipped byte inside the published delta's sidecar fails the
    // FNV checksum at load, exactly like a base sidecar would.
    let sidecar = dir.join("append-000001").join("new_y.bin");
    let original = std::fs::read(&sidecar).unwrap();
    let mut bytes = original.clone();
    bytes[original.len() / 2] ^= 0x01;
    std::fs::write(&sidecar, &bytes).unwrap();
    let err = load_err(&dir);
    assert!(err.contains("checksum"), "bitflipped delta sidecar: {err}");
    std::fs::write(&sidecar, &original).unwrap();

    // Restored, the base + delta replays into the appended model
    // bitwise — prediction cache included.
    let probes = &ds.test_x[k * ds.d..(k + 32) * ds.d];
    let want = gp.predict(probes).unwrap();
    let (gp2, _) = coordinator::load_model(&cfg, &dir).unwrap();
    assert_eq!(gp2.n(), n_before + k);
    let got = gp2.predict(probes).unwrap();
    for i in 0..want.mean.len() {
        assert_eq!(got.mean[i].to_bits(), want.mean[i].to_bits());
        assert_eq!(got.var[i].to_bits(), want.var[i].to_bits());
    }

    let _ = std::fs::remove_dir_all(&dir);
}
