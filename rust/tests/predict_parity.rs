//! Prediction parity suite: batched, chunked, partitioned predictions
//! (means AND variances) must match the dense Cholesky reference on small
//! n, stay bitwise-deterministic across chunk sizes and worker counts,
//! and survive the chunk-boundary edge cases (m = 1, m = chunk +/- 1).

use std::sync::Arc;

use exactgp::config::{Backend, Config};
use exactgp::data::{Dataset, RawData};
use exactgp::exec::transport::subprocess::SubprocessOptions;
use exactgp::exec::transport::BackendSpec;
use exactgp::exec::{pool::DevicePool, TileSpec};
use exactgp::gp::cholesky::CholeskyGp;
use exactgp::gp::exact::ExactGp;
use exactgp::kernels::KernelKind;
use exactgp::util::rng::Rng;

fn toy_dataset(n_total: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed, 0);
    let mut raw = RawData {
        name: "toy".into(),
        d,
        x: (0..n_total * d).map(|_| rng.normal()).collect(),
        y: vec![0.0; n_total],
    };
    for i in 0..n_total {
        let xi = raw.x[i * d];
        let xj = raw.x[i * d + d - 1];
        raw.y[i] = (1.5 * xi).sin() + 0.3 * xj + 0.05 * rng.normal();
    }
    raw.prepare(32, &mut rng)
}

/// An exact GP with full-rank LOVE cache and tight solves: its predictive
/// moments must agree with the dense Cholesky GP to solver tolerance.
fn exact_gp(ds: &Dataset, workers: usize) -> ExactGp {
    let spec = TileSpec { r: 16, c: 32, t: 16, d: 32 };
    let mut cfg = Config::default();
    cfg.backend = Backend::Native;
    cfg.predict_tol = 1e-9;
    cfg.variance_rank = ds.n_train(); // full rank => exact variances
    cfg.precond_rank = 20;
    cfg.workers = workers;
    // cfg.transport defaults from EXACTGP_TRANSPORT, so the CI subprocess
    // leg pushes this whole suite through worker processes.
    let backend = BackendSpec::from_config(&cfg, KernelKind::Matern32, false, spec.d, spec).unwrap();
    let mut opts = SubprocessOptions::from_config(&cfg);
    opts.worker_bin = Some(env!("CARGO_BIN_EXE_exactgp").into());
    let pool = Arc::new(DevicePool::with_transport(cfg.transport, workers, &backend, opts).unwrap());
    let mut gp = ExactGp::new(&cfg, KernelKind::Matern32, ds, pool, spec);
    let mut rng = Rng::new(301, 0);
    gp.precompute(&mut rng).unwrap();
    gp
}

fn oracle(gp: &ExactGp, ds: &Dataset) -> exactgp::gp::Predictions {
    let mut chol = CholeskyGp::new(
        KernelKind::Matern32,
        gp.hypers.clone(),
        ds.train_x.clone(),
        ds.train_y.clone(),
        ds.d,
    );
    chol.predict(&ds.test_x).unwrap()
}

#[test]
fn chunked_batched_predictions_match_cholesky() {
    let ds = toy_dataset(200, 2, 401);
    let gp = exact_gp(&ds, 2);
    let want = oracle(&gp, &ds);
    let m = ds.n_test();
    // Chunk sizes straddling every boundary: single point, sub-tile,
    // tile-aligned, m - 1, m, m + 1, and 0 (= one chunk for the batch).
    for chunk in [0usize, 1, 7, 16, 64, m - 1, m, m + 1] {
        let got = gp.predict_with_chunk(&ds.test_x, chunk).unwrap();
        assert_eq!(got.mean.len(), m);
        for i in 0..m {
            assert!(
                (got.mean[i] - want.mean[i]).abs() < 1e-4,
                "chunk={chunk} mean[{i}]: {} vs {}",
                got.mean[i],
                want.mean[i]
            );
            assert!(
                (got.var[i] - want.var[i]).abs() < 1e-3,
                "chunk={chunk} var[{i}]: {} vs {}",
                got.var[i],
                want.var[i]
            );
        }
    }
}

#[test]
fn config_chunking_matches_explicit_chunking() {
    let ds = toy_dataset(180, 2, 402);
    let gp = exact_gp(&ds, 2);
    // The config-planned path (predict) and an explicit whole-batch chunk
    // must be bitwise-identical: chunking never changes a row's result.
    let auto = gp.predict(&ds.test_x).unwrap();
    let one = gp.predict_with_chunk(&ds.test_x, 0).unwrap();
    assert_eq!(auto.mean, one.mean);
    assert_eq!(auto.var, one.var);
}

#[test]
fn bitwise_deterministic_across_workers_and_chunks() {
    let ds = toy_dataset(160, 2, 403);
    let mut reference: Option<exactgp::gp::Predictions> = None;
    for workers in [1usize, 2, 3] {
        let gp = exact_gp(&ds, workers);
        for chunk in [0usize, 5, 32, ds.n_test()] {
            let got = gp.predict_with_chunk(&ds.test_x, chunk).unwrap();
            match &reference {
                None => reference = Some(got),
                Some(r) => {
                    assert_eq!(
                        r.mean, got.mean,
                        "means differ at workers={workers} chunk={chunk}"
                    );
                    assert_eq!(
                        r.var, got.var,
                        "variances differ at workers={workers} chunk={chunk}"
                    );
                }
            }
        }
    }
}

#[test]
fn single_point_and_boundary_batches() {
    let ds = toy_dataset(150, 2, 404);
    let gp = exact_gp(&ds, 2);
    let want = oracle(&gp, &ds);
    let d = ds.d;
    // m = 1: one query through the full chunked path.
    let one = gp.predict_with_chunk(&ds.test_x[..d], 4).unwrap();
    assert_eq!(one.mean.len(), 1);
    assert!((one.mean[0] - want.mean[0]).abs() < 1e-4);
    assert!((one.var[0] - want.var[0]).abs() < 1e-3);
    // m = chunk - 1 and m = chunk + 1 around a chunk of 8.
    for m in [7usize, 8, 9] {
        let got = gp.predict_with_chunk(&ds.test_x[..m * d], 8).unwrap();
        assert_eq!(got.mean.len(), m);
        for i in 0..m {
            assert!((got.mean[i] - want.mean[i]).abs() < 1e-4, "m={m} i={i}");
            assert!((got.var[i] - want.var[i]).abs() < 1e-3, "m={m} i={i}");
        }
    }
    // Empty batch: legal, returns empty predictions.
    let empty = gp.predict_with_chunk(&[], 8).unwrap();
    assert!(empty.mean.is_empty() && empty.var.is_empty());
}

#[test]
fn prediction_counters_track_served_points() {
    let ds = toy_dataset(150, 2, 405);
    let gp = exact_gp(&ds, 2);
    let before = gp.accounting().snapshot();
    let m = ds.n_test();
    let _ = gp.predict_with_chunk(&ds.test_x, 16).unwrap();
    let delta = gp.accounting().snapshot().delta(&before);
    assert_eq!(delta.predict_points, m as u64);
    assert_eq!(delta.predict_chunks, m.div_ceil(16) as u64);
}
