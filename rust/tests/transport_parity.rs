//! Transport parity: the subprocess transport must be *observably
//! identical* to the local thread pool — bitwise-equal MVMs, gradient
//! MVMs, cached replays, and end-to-end train → checkpoint → predict
//! results, with the same accounting counters arriving over IPC — plus
//! the fault-handling contract: a worker killed or hung mid-solve is
//! respawned, its in-flight jobs are resubmitted, and the batch still
//! converges to the same bits.

use std::sync::Arc;
use std::time::Duration;

use exactgp::config::{Backend, Config, TransportKind};
use exactgp::coordinator;
use exactgp::data::synthetic::Scale;
use exactgp::exec::transport::subprocess::SubprocessOptions;
use exactgp::exec::transport::BackendSpec;
use exactgp::exec::{pool::DevicePool, CrossKernelOp, PaddedData, PartitionedKernelOp, TileSpec};
use exactgp::faults::FaultPlan;
use exactgp::gp::exact::{ExactGp, Recipe};
use exactgp::kernels::{Hypers, KernelKind};
use exactgp::linalg::Mat;
use exactgp::metrics::Accounting;
use exactgp::partition::Plan;
use exactgp::solvers::BatchMvm;
use exactgp::util::rng::Rng;

const SPEC: TileSpec = TileSpec { r: 4, c: 8, t: 2, d: 3 };

fn backend() -> BackendSpec {
    BackendSpec::Native { kernel: KernelKind::Matern32, ard: false, spec: SPEC, radius: 1.0 }
}

/// A compact-support backend: same tile geometry, Wendland C2 kernel at
/// an explicit support radius — the configuration under which the bbox
/// proof can skip tiles.
fn compact_backend(radius: f64) -> BackendSpec {
    BackendSpec::Native { kernel: KernelKind::WendlandC2, ard: false, spec: SPEC, radius }
}

/// Options pinned to the test build's own `exactgp` binary, so the
/// suite never depends on PATH or the env resolution order.
fn opts() -> SubprocessOptions {
    SubprocessOptions {
        worker_bin: Some(env!("CARGO_BIN_EXE_exactgp").into()),
        ..SubprocessOptions::default()
    }
}

fn pool(kind: TransportKind, workers: usize, o: SubprocessOptions) -> Arc<DevicePool> {
    Arc::new(DevicePool::with_transport(kind, workers, &backend(), o).unwrap())
}

fn cpool(kind: TransportKind, workers: usize, radius: f64) -> Arc<DevicePool> {
    Arc::new(DevicePool::with_transport(kind, workers, &compact_backend(radius), opts()).unwrap())
}

fn build_op(pool: Arc<DevicePool>, x: &[f64], rpp: usize, cache_budget: usize) -> PartitionedKernelOp {
    let data = Arc::new(PaddedData::new(x, SPEC.d, &SPEC));
    let plan = Plan::with_rows(data.n_pad, data.n_pad, rpp);
    let hypers = Hypers {
        log_lengthscales: vec![0.15],
        log_outputscale: 0.1,
        log_noise: (0.3f64).ln(),
    };
    PartitionedKernelOp::square(data, pool, plan, SPEC, hypers, Arc::new(Accounting::default()))
        .with_cache_budget(cache_budget)
}

fn toy(n: usize) -> (Vec<f64>, Mat) {
    let mut rng = Rng::new(901, n as u64);
    let x: Vec<f64> = (0..n * SPEC.d).map(|_| rng.normal()).collect();
    let v = Mat::from_vec(n, SPEC.t, rng.normal_vec(n * SPEC.t));
    (x, v)
}

#[test]
fn mvm_and_grads_bitwise_parity_across_worker_counts() {
    // n = 45 misaligns with every tile dimension on purpose.
    let (x, v) = toy(45);
    let reference = build_op(pool(TransportKind::Local, 1, opts()), &x, 16, 0).mvm(&v);
    let (ref_kv, ref_gs) =
        build_op(pool(TransportKind::Local, 1, opts()), &x, 16, 0).apply_grads(&v);
    for workers in [1usize, 2, 3] {
        for rpp in [SPEC.r, SPEC.r * 3, 1024] {
            let op = build_op(pool(TransportKind::Subprocess, workers, opts()), &x, rpp, 0);
            let got = op.mvm(&v);
            assert_eq!(
                got.data, reference.data,
                "subprocess MVM diverged (workers={workers} rpp={rpp})"
            );
            let (kv, gs) = op.apply_grads(&v);
            assert_eq!(kv.data, ref_kv.data, "gradient KV diverged (workers={workers})");
            assert_eq!(gs.len(), ref_gs.len());
            for (g, rg) in gs.iter().zip(&ref_gs) {
                assert_eq!(g.data, rg.data, "lengthscale gradient diverged");
            }
        }
    }
}

#[test]
fn cached_replay_and_counters_match_over_ipc() {
    let (x, v) = toy(40);
    let local = build_op(pool(TransportKind::Local, 2, opts()), &x, SPEC.r * 2, 64 << 20);
    let sub = build_op(pool(TransportKind::Subprocess, 2, opts()), &x, SPEC.r * 2, 64 << 20);

    for op in [&local, &sub] {
        let cold = op.mvm(&v);
        let warm = op.mvm(&v);
        assert_eq!(cold.data, warm.data, "cached replay changed the result");
    }
    assert_eq!(local.mvm(&v).data, sub.mvm(&v).data, "transports diverged");

    // The worker-side counters must arrive intact over the wire: fills,
    // hits, tile execs, and device-byte accounting all equal the local
    // transport's numbers.
    let ls = local.acct.snapshot();
    let ss = sub.acct.snapshot();
    assert!(ls.cache_fills > 0 && ls.cache_hits > 0, "cache never engaged");
    assert_eq!(ss.cache_fills, ls.cache_fills, "cache_fills diverged over IPC");
    assert_eq!(ss.cache_hits, ls.cache_hits, "cache_hits diverged over IPC");
    assert_eq!(ss.tile_execs, ls.tile_execs, "tile_execs diverged over IPC");
    assert_eq!(ss.bytes_to_device, ls.bytes_to_device);
    assert_eq!(ss.bytes_from_device, ls.bytes_from_device);

    // And only the subprocess transport moves IPC bytes.
    assert_eq!(ls.ipc_bytes_tx, 0);
    assert_eq!(ls.ipc_bytes_rx, 0);
    assert!(ss.ipc_bytes_tx > 0, "no request bytes counted");
    assert!(ss.ipc_bytes_rx > 0, "no response bytes counted");
}

fn base_cfg(workers: usize, transport: TransportKind) -> Config {
    let mut cfg = Config::default();
    cfg.backend = Backend::Native;
    cfg.scale = Scale { train_cap: 320 };
    cfg.workers = workers;
    cfg.transport = transport;
    cfg.pretrain_subset = 64;
    cfg.pretrain_lbfgs_steps = 2;
    cfg.pretrain_adam_steps = 2;
    cfg.finetune_adam_steps = 2;
    cfg.precond_rank = 16;
    cfg.variance_rank = 24;
    cfg
}

fn trained(cfg: &Config) -> (ExactGp, exactgp::data::Dataset) {
    let ds = coordinator::load_dataset(cfg, "bike", 0).unwrap();
    let (pool, spec) = coordinator::make_pool(cfg, ds.d).unwrap();
    let mut rng = Rng::new(11, 0);
    let mut gp = ExactGp::new(cfg, cfg.kernel, &ds, pool, spec);
    gp.train(Recipe::paper_default(cfg), &mut rng).unwrap();
    gp.precompute(&mut rng).unwrap();
    (gp, ds)
}

#[test]
fn end_to_end_train_checkpoint_predict_is_bitwise_identical() {
    // The full pipeline — train, checkpoint, restore, predict — run once
    // per transport; every prediction must agree to the last bit. The
    // subprocess leg resolves the worker binary from the environment the
    // way a real run does (test binaries live in target/*/deps and find
    // the sibling exactgp CLI).
    let (gp_local, ds) = trained(&base_cfg(2, TransportKind::Local));
    let want = gp_local.predict(&ds.test_x).unwrap();

    let cfg_sub = base_cfg(2, TransportKind::Subprocess);
    let (gp_sub, ds_sub) = trained(&cfg_sub);
    assert_eq!(ds_sub.test_x, ds.test_x);
    let got = gp_sub.predict(&ds_sub.test_x).unwrap();
    assert_eq!(got.mean.len(), want.mean.len());
    for i in 0..want.mean.len() {
        assert_eq!(got.mean[i].to_bits(), want.mean[i].to_bits(), "mean[{i}] differs");
        assert_eq!(got.var[i].to_bits(), want.var[i].to_bits(), "var[{i}] differs");
    }

    // Checkpoint written by the subprocess-trained model, restored and
    // served on the subprocess transport.
    let dir = std::env::temp_dir()
        .join(format!("exactgp_it_transport_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    gp_sub.save(&dir, &ds_sub).unwrap();
    let (gp2, ds2) = coordinator::load_model(&cfg_sub, &dir).unwrap();
    let snap = gp2.accounting().snapshot();
    assert_eq!(snap.mbcg_solves, 0, "restore ran a solve");
    let again = gp2.predict(&ds2.test_x).unwrap();
    for i in 0..want.mean.len() {
        assert_eq!(again.mean[i].to_bits(), want.mean[i].to_bits());
        assert_eq!(again.var[i].to_bits(), want.var[i].to_bits());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn killed_worker_is_respawned_and_jobs_are_resubmitted() {
    let (x, v) = toy(64); // 64 rows / r=4 at rpp=4 -> plenty of jobs
    let want = build_op(pool(TransportKind::Local, 2, opts()), &x, SPEC.r, 0).mvm(&v);

    // Worker 1's first incarnation exits(23) after its first job, with
    // the rest of its queue in flight — the coordinator must respawn it,
    // resubmit, and still produce identical bits. Worker 1 (not 0) also
    // proves fault seams are not limited to worker 0 like the old hook.
    let o = SubprocessOptions {
        plan: Arc::new(FaultPlan::parse("worker.kill@1:1").unwrap()),
        ..opts()
    };
    let op = build_op(pool(TransportKind::Subprocess, 2, o), &x, SPEC.r, 0);
    let got = op.mvm(&v);
    assert_eq!(got.data, want.data, "post-respawn results diverged");

    let snap = op.acct.snapshot();
    assert!(snap.worker_restarts >= 1, "no restart was counted");
    assert!(snap.jobs_resubmitted >= 1, "no resubmission was counted");

    // The revived pool keeps working: a second MVM on the same operator
    // (same generation, fresh uploads already done) is also identical.
    let again = op.mvm(&v);
    assert_eq!(again.data, want.data, "pool unhealthy after a respawn");
}

#[test]
fn hung_worker_times_out_and_the_solve_completes() {
    let (x, v) = toy(48);
    let want = build_op(pool(TransportKind::Local, 2, opts()), &x, SPEC.r, 0).mvm(&v);
    let o = SubprocessOptions {
        plan: Arc::new(FaultPlan::parse("worker.hang@0:1").unwrap()),
        job_timeout: Some(Duration::from_secs(2)),
        ..opts()
    };
    let op = build_op(pool(TransportKind::Subprocess, 2, o), &x, SPEC.r, 0);
    let got = op.mvm(&v);
    assert_eq!(got.data, want.data, "post-timeout results diverged");
    assert!(op.acct.snapshot().worker_restarts >= 1, "hang never tripped the timeout");
}

#[test]
fn env_hooks_arm_fault_injection_and_timeout() {
    // from_env is how `EXACTGP_TRANSPORT=subprocess cargo test` runs pick
    // up fault plans and the timeout without code changes. The legacy
    // EXACTGP_KILL_WORKER_AFTER_JOBS variable stays an alias for
    // worker.kill@0:N.
    std::env::set_var("EXACTGP_KILL_WORKER_AFTER_JOBS", "3");
    std::env::set_var("EXACTGP_WORKER_TIMEOUT_SECS", "7");
    let o = SubprocessOptions::from_env();
    std::env::remove_var("EXACTGP_KILL_WORKER_AFTER_JOBS");
    std::env::remove_var("EXACTGP_WORKER_TIMEOUT_SECS");
    assert_eq!(o.plan.worker_arming(0), (3, 0));
    assert_eq!(o.plan.worker_arming(1), (0, 0));
    assert_eq!(o.job_timeout, Some(Duration::from_secs(7)));

    // "0" disables rather than arming a kill-before-first-job.
    std::env::set_var("EXACTGP_KILL_WORKER_AFTER_JOBS", "0");
    let o = SubprocessOptions::from_env();
    std::env::remove_var("EXACTGP_KILL_WORKER_AFTER_JOBS");
    assert!(o.plan.is_inert());

    // EXACTGP_FAULTS speaks the full seam grammar, any worker index.
    std::env::set_var("EXACTGP_FAULTS", "worker.hang@1:2");
    let o = SubprocessOptions::from_env();
    std::env::remove_var("EXACTGP_FAULTS");
    assert_eq!(o.plan.worker_arming(1), (0, 2));
    // Arming is consumed at spawn: a respawn of the same worker id comes
    // up clean (the old worker-0-first-incarnation special case, now a
    // property of every seam).
    assert_eq!(o.plan.worker_arming(1), (0, 0));
}

#[test]
fn zero_workers_is_a_config_error_on_both_transports() {
    for kind in [TransportKind::Local, TransportKind::Subprocess] {
        let err = DevicePool::with_transport(kind, 0, &backend(), opts())
            .err()
            .expect("workers=0 must not construct a pool")
            .to_string();
        assert!(err.contains("at least one worker"), "unhelpful error: {err}");
    }
}

// ---------------------------------------------------------------------------
// Sparsity parity: proved tile skipping must be *bitwise invisible*.
// ---------------------------------------------------------------------------

/// Two tight clusters in d = 3 (SPEC.d), `sep` apart along the diagonal,
/// rows pre-sorted so every r x c tile is pure one blob. With a compact
/// kernel whose scaled support radius is far below the cluster gap, every
/// cross-blob tile is provably zero; within-blob tiles stay live.
/// `n_per = 24` gives n = 48, divisible by both r = 4 and c = 8, so the
/// square op has no padding rows to think about.
fn blobs(n_per: usize, sep: f64) -> Vec<f64> {
    let mut rng = Rng::new(902, n_per as u64);
    let mut x = Vec::with_capacity(2 * n_per * SPEC.d);
    for blob in 0..2 {
        let center = blob as f64 * sep;
        for _ in 0..n_per * SPEC.d {
            x.push(center + 0.3 * rng.normal());
        }
    }
    x
}

/// A square op over the compact backend with the skip/dense decision
/// pinned explicitly (not via the env hook, so parallel tests can't race
/// on process-global state).
fn build_compact_op(
    pool: Arc<DevicePool>,
    x: &[f64],
    rpp: usize,
    cache_budget: usize,
    force_dense: bool,
) -> PartitionedKernelOp {
    let data = Arc::new(PaddedData::new(x, SPEC.d, &SPEC));
    let plan = Plan::with_rows(data.n_pad, data.n_pad, rpp);
    let hypers = Hypers {
        log_lengthscales: vec![0.15],
        log_outputscale: 0.1,
        log_noise: (0.3f64).ln(),
    };
    PartitionedKernelOp::square(data, pool, plan, SPEC, hypers, Arc::new(Accounting::default()))
        .with_cache_budget(cache_budget)
        .with_force_dense(force_dense)
}

#[test]
fn proved_tile_skipping_is_bitwise_invisible_on_both_transports() {
    // A skipped tile contributes exactly +0.0 to every accumulator a dense
    // materialization would have touched, so MVMs and gradient traces must
    // agree with the force-dense op to the last bit — across transports,
    // worker counts, and partition sub-splits. The *decision* is made at
    // fixed tile granularity, so the skip counters are invariant too.
    let x = blobs(24, 10.0);
    let n = 48;
    let radius = 2.0;
    let mut rng = Rng::new(903, 0);
    let v = Mat::from_vec(n, SPEC.t, rng.normal_vec(n * SPEC.t));

    let dense = build_compact_op(cpool(TransportKind::Local, 1, radius), &x, 16, 0, true);
    let want = dense.mvm(&v);
    let (want_kv, want_gs) = dense.apply_grads(&v);
    let dsnap = dense.acct.snapshot();
    assert_eq!(dsnap.tiles_skipped, 0, "force-dense must never skip");
    assert!(dsnap.tiles_total > 0, "no candidate tiles counted");

    for kind in [TransportKind::Local, TransportKind::Subprocess] {
        for workers in [1usize, 3] {
            for rpp in [SPEC.r, SPEC.r * 3] {
                let tag = format!("{kind:?} workers={workers} rpp={rpp}");
                let op = build_compact_op(cpool(kind, workers, radius), &x, rpp, 0, false);
                assert_eq!(op.mvm(&v).data, want.data, "MVM diverged ({tag})");
                let (kv, gs) = op.apply_grads(&v);
                assert_eq!(kv.data, want_kv.data, "gradient KV diverged ({tag})");
                assert_eq!(gs.len(), want_gs.len());
                for (g, rg) in gs.iter().zip(&want_gs) {
                    assert_eq!(g.data, rg.data, "lengthscale gradient diverged ({tag})");
                }
                let snap = op.acct.snapshot();
                assert!(snap.tiles_skipped > 0, "cross-blob tiles were not skipped ({tag})");
                assert!(
                    snap.tiles_skipped < snap.tiles_total,
                    "within-blob tiles must stay live ({tag})"
                );
                // Same candidate count and same skip count regardless of
                // how jobs were split: the proof is per fixed-size tile.
                assert_eq!(snap.tiles_total, dsnap.tiles_total, "candidate count drifted ({tag})");
            }
        }
    }
}

#[test]
fn cross_kernel_skipping_matches_force_dense_bitwise() {
    // The rect (test x train) path: queries sit on blob A only, so every
    // blob-B column strip of K(X*, X) is provably zero. Skip and
    // force-dense must agree bitwise on both transports, with and without
    // row chunking (chunk padding rows are discarded at assembly).
    let x = blobs(24, 10.0);
    let n = 48;
    let radius = 2.0;
    let mut rng = Rng::new(904, 0);
    let q: Vec<f64> = (0..12 * SPEC.d).map(|_| 0.3 * rng.normal()).collect();
    // 5 RHS columns > t = 2 so the cache budget path engages.
    let v = Mat::from_vec(n, 5, rng.normal_vec(n * 5));
    let hypers = Hypers {
        log_lengthscales: vec![0.15],
        log_outputscale: 0.1,
        log_noise: (0.3f64).ln(),
    };

    let mk = |kind: TransportKind, force_dense: bool, chunk: usize| {
        let data = Arc::new(PaddedData::new(&x, SPEC.d, &SPEC));
        let mut op = CrossKernelOp::new(
            data,
            cpool(kind, 2, radius),
            SPEC,
            hypers.clone(),
            Arc::new(Accounting::default()),
        )
        .with_cache_budget(64 << 20)
        .with_chunk_rows(chunk)
        .with_force_dense(force_dense);
        let kv = op.apply(&q, SPEC.d, &v);
        let snap = op.acct.snapshot();
        (kv, snap)
    };

    let (want, dsnap) = mk(TransportKind::Local, true, 0);
    assert_eq!(dsnap.tiles_skipped, 0, "force-dense must never skip");
    for kind in [TransportKind::Local, TransportKind::Subprocess] {
        for chunk in [0usize, 5] {
            let (got, snap) = mk(kind, false, chunk);
            assert_eq!(got.data, want.data, "cross-op diverged ({kind:?} chunk={chunk})");
            assert!(snap.tiles_skipped > 0, "rect path never skipped ({kind:?} chunk={chunk})");
        }
    }
}

#[test]
fn set_hypers_flips_tiles_between_skipped_and_live_without_stale_reads() {
    // A lengthscale update changes which tiles the bbox proof can clear.
    // Short lengthscale: the blobs sit ~15 scaled units apart, far past
    // the radius — cross-blob tiles skip. Long lengthscale: every scaled
    // distance shrinks below the radius — those same tiles come alive, and
    // the generation bump must refill (not replay) any cached strips.
    // Then back again. At every phase the skipping op must match the
    // force-dense op bitwise.
    let x = blobs(24, 10.0);
    let n = 48;
    let radius = 2.0;
    let mut rng = Rng::new(905, 0);
    let v = Mat::from_vec(n, SPEC.t, rng.normal_vec(n * SPEC.t));
    let h0 = Hypers {
        log_lengthscales: vec![0.15],
        log_outputscale: 0.1,
        log_noise: (0.3f64).ln(),
    };
    let wide = Hypers { log_lengthscales: vec![2.5], ..h0.clone() };

    for kind in [TransportKind::Local, TransportKind::Subprocess] {
        for budget in [0usize, 64 << 20] {
            let tag = format!("{kind:?} budget={budget}");
            let mut skip = build_compact_op(cpool(kind, 2, radius), &x, SPEC.r * 2, budget, false);
            let mut dense = build_compact_op(cpool(kind, 2, radius), &x, SPEC.r * 2, budget, true);

            // Phase 1: short lengthscale — cross-blob tiles skip. Run the
            // MVM twice so the cached-replay path is exercised too.
            for pass in 0..2 {
                assert_eq!(skip.mvm(&v).data, dense.mvm(&v).data, "phase 1 pass {pass} ({tag})");
            }
            let s1 = skip.acct.snapshot();
            assert!(s1.tiles_skipped > 0, "nothing skipped in phase 1 ({tag})");
            assert_eq!(s1.tiles_total, dense.acct.snapshot().tiles_total, "({tag})");
            if budget > 0 {
                assert!(s1.cache_fills > 0 && s1.cache_hits > 0, "cache never engaged ({tag})");
            }

            // Phase 2: long lengthscale — previously-skipped tiles are now
            // live; no tile may skip, and no stale strip may be replayed.
            skip.set_hypers(wide.clone());
            dense.set_hypers(wide.clone());
            for pass in 0..2 {
                assert_eq!(skip.mvm(&v).data, dense.mvm(&v).data, "phase 2 pass {pass} ({tag})");
            }
            let s2 = skip.acct.snapshot();
            assert_eq!(s2.delta(&s1).tiles_skipped, 0, "wide lengthscale still skipped ({tag})");
            if budget > 0 {
                assert!(
                    s2.delta(&s1).cache_fills > 0,
                    "tiles that flipped live never refilled the cache ({tag})"
                );
            }

            // Phase 3: back to the short lengthscale — tiles flip back to
            // skipped and results still agree with force-dense.
            skip.set_hypers(h0.clone());
            dense.set_hypers(h0.clone());
            assert_eq!(skip.mvm(&v).data, dense.mvm(&v).data, "phase 3 ({tag})");
            let s3 = skip.acct.snapshot();
            assert!(s3.delta(&s2).tiles_skipped > 0, "tiles did not flip back ({tag})");
        }
    }
}

#[test]
fn sparse_end_to_end_train_checkpoint_predict_matches_force_dense() {
    // Wendland C2 on the 3droad stand-in (d = 3, where phi_{3,1} is a
    // valid positive-definite kernel), locality-sorted so cross-cluster
    // tiles are provably zero. The whole pipeline — pretrain, optimizer
    // steps, precompute, predict — must produce bitwise-identical results
    // with tile skipping on and off (EXACTGP_FORCE_DENSE_TILES=1), on both
    // transports, while the skipping leg actually skips tiles. This is
    // the only test in the binary that uses the env hook; it is safe from
    // races because every other concurrent test either pins the decision
    // via with_force_dense or runs Matern32, for which force-dense is a
    // no-op (no support cutoff exists to skip).
    let spec = TileSpec { r: 4, c: 8, t: 2, d: 3 };
    let run = |cfg: &Config, force_dense: bool| {
        if force_dense {
            std::env::set_var("EXACTGP_FORCE_DENSE_TILES", "1");
        }
        // The env hook is read at op construction, so it must stay set
        // through train + precompute + predict for the dense leg.
        let ds = coordinator::load_dataset(cfg, "3droad", 0).unwrap();
        let bs = BackendSpec::from_config(cfg, cfg.kernel, cfg.ard, spec.d, spec).unwrap();
        let pool =
            Arc::new(DevicePool::with_transport(cfg.transport, cfg.workers, &bs, opts()).unwrap());
        let mut rng = Rng::new(11, 0);
        let mut gp = ExactGp::new(cfg, cfg.kernel, &ds, pool, spec);
        gp.train(Recipe::paper_default(cfg), &mut rng).unwrap();
        gp.precompute(&mut rng).unwrap();
        let preds = gp.predict(&ds.test_x).unwrap();
        if force_dense {
            std::env::remove_var("EXACTGP_FORCE_DENSE_TILES");
        }
        (gp, ds, preds)
    };

    for kind in [TransportKind::Local, TransportKind::Subprocess] {
        let mut cfg = base_cfg(2, kind);
        cfg.kernel = KernelKind::WendlandC2;
        cfg.support_radius = 0.5;
        cfg.locality_sort = true;

        let (gp_dense, _, want) = run(&cfg, true);
        let dsnap = gp_dense.accounting().snapshot();
        assert_eq!(dsnap.tiles_skipped, 0, "force-dense must never skip ({kind:?})");
        assert!(dsnap.tiles_total > 0, "no candidate tiles counted ({kind:?})");

        let (gp_skip, ds, got) = run(&cfg, false);
        let ssnap = gp_skip.accounting().snapshot();
        assert!(ssnap.tiles_skipped > 0, "sparse training never skipped a tile ({kind:?})");
        assert_eq!(ssnap.tiles_total, dsnap.tiles_total, "candidate tiles diverged ({kind:?})");
        for (i, (a, b)) in gp_dense.hypers.to_vec().iter().zip(gp_skip.hypers.to_vec()).enumerate()
        {
            assert_eq!(a.to_bits(), b.to_bits(), "trained hyper {i} diverged ({kind:?})");
        }
        assert_eq!(got.mean.len(), want.mean.len());
        for i in 0..want.mean.len() {
            assert_eq!(got.mean[i].to_bits(), want.mean[i].to_bits(), "mean[{i}] ({kind:?})");
            assert_eq!(got.var[i].to_bits(), want.var[i].to_bits(), "var[{i}] ({kind:?})");
        }

        // Checkpoint round trip on the skipping leg: restore onto a pool
        // with the *same* tile geometry and predict again — the sparse
        // model serves the same bits it trained.
        let dir = std::env::temp_dir()
            .join(format!("exactgp_it_sparse_{}_{kind:?}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        gp_skip.save(&dir, &ds).unwrap();
        let bs = BackendSpec::from_config(&cfg, cfg.kernel, cfg.ard, spec.d, spec).unwrap();
        let pool2 =
            Arc::new(DevicePool::with_transport(cfg.transport, cfg.workers, &bs, opts()).unwrap());
        let (gp2, ds2) = ExactGp::load(&dir, &cfg, pool2, spec).unwrap();
        let again = gp2.predict(&ds2.test_x).unwrap();
        for i in 0..want.mean.len() {
            assert_eq!(again.mean[i].to_bits(), want.mean[i].to_bits(), "restored mean[{i}]");
            assert_eq!(again.var[i].to_bits(), want.var[i].to_bits(), "restored var[{i}]");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
