//! Sparsity soundness: the bbox tile-skip proof may be loose, but it must
//! NEVER be unsound. For every tile the proof clears, the production f32
//! tile path must materialize an exactly-zero block, and a dense per-pair
//! f64 evaluation of every true (row, col) pair must agree — across
//! kernels, ARD settings, support radii, and adversarial data layouts
//! (clusters, interleavings, duplicates, tile-misaligned sizes). The
//! proof must also be monotone under sub-splitting: any sub-range of a
//! proved row block is still proved, so no job split can resurrect a
//! skipped tile. An assertion failure in this file means a skipped tile
//! could have contributed nonzero mass to an MVM — a correctness bug, not
//! a tuning issue.

use exactgp::config::{Backend, Config};
use exactgp::exec::{backend_factory, PaddedData, TileBackend, TileSpec};
use exactgp::kernels::{Hypers, KernelEval, KernelKind};
use exactgp::partition::BBox;
use exactgp::util::rng::Rng;

const SPEC: TileSpec = TileSpec { r: 4, c: 8, t: 2, d: 3 };
const COMPACT: [KernelKind; 3] =
    [KernelKind::WendlandC2, KernelKind::WendlandC4, KernelKind::TaperedMatern32];

fn make_backend(kind: KernelKind, ard: bool, radius: f64) -> Box<dyn TileBackend> {
    let mut cfg = Config::default();
    cfg.backend = Backend::Native;
    cfg.support_radius = radius;
    backend_factory(&cfg, kind, ard, SPEC.d, SPEC).unwrap()(0).unwrap()
}

fn hypers(ard: bool) -> Hypers {
    Hypers {
        log_lengthscales: if ard { vec![0.3, -0.2, 0.1] } else { vec![0.15] },
        log_outputscale: 0.1,
        log_noise: (0.3f64).ln(),
    }
}

/// Kernel-only theta in the layout the native backend consumes (true
/// d == SPEC.d here, so no padding entries are needed).
fn theta(h: &Hypers) -> Vec<f32> {
    h.theta_f32()
}

/// Adversarial data layouts, flat (n, 3) row-major.
fn cases() -> Vec<(&'static str, Vec<f64>)> {
    let mut out = Vec::new();

    // Two tight blobs 10 apart, rows sorted by blob: the canonical
    // skippable layout (every tile pure one blob).
    let mut rng = Rng::new(501, 0);
    let mut sorted = Vec::new();
    for blob in 0..2 {
        for _ in 0..24 * SPEC.d {
            sorted.push(blob as f64 * 10.0 + 0.3 * rng.normal());
        }
    }
    // The same points interleaved row-by-row: every tile straddles both
    // blobs, so (almost) nothing is provable — the proof must stay sound
    // while being maximally loose.
    let mut interleaved = Vec::new();
    for i in 0..24 {
        for src in [i, 24 + i] {
            interleaved.extend_from_slice(&sorted[src * SPEC.d..(src + 1) * SPEC.d]);
        }
    }
    out.push(("sorted-blobs", sorted));
    out.push(("interleaved-blobs", interleaved));

    // Uniform box, tile-misaligned n.
    let mut rng = Rng::new(502, 0);
    out.push(("uniform-45", (0..45 * SPEC.d).map(|_| rng.uniform_in(-4.0, 4.0)).collect()));

    // Four clusters at the corners of a square, sorted, n = 33 (misaligned
    // with r, c, and the cluster size).
    let mut rng = Rng::new(503, 0);
    let mut clusters = Vec::new();
    for i in 0..33 {
        let (cx, cy) = ([-6.0, 6.0][(i / 9) % 2], [-6.0, 6.0][(i / 18) % 2]);
        clusters.push(cx + 0.2 * rng.normal());
        clusters.push(cy + 0.2 * rng.normal());
        clusters.push(0.2 * rng.normal());
    }
    out.push(("four-clusters-33", clusters));

    // A point duplicated 17 times (zero-width bbox) plus a far cluster.
    let mut rng = Rng::new(504, 0);
    let mut dupes = Vec::new();
    for _ in 0..17 {
        dupes.extend_from_slice(&[1.25, -0.5, 3.0]);
    }
    for _ in 0..16 {
        for j in 0..SPEC.d {
            dupes.push(if j == 0 { 20.0 } else { 0.0 } + 0.1 * rng.normal());
        }
    }
    out.push(("duplicates-plus-far", dupes));

    // A long line: wide spread along one axis, degenerate in the others.
    let mut line = Vec::new();
    for i in 0..64 {
        line.extend_from_slice(&[i as f64 * 0.7, 0.0, 0.0]);
    }
    out.push(("line-64", line));

    out
}

/// Padded row block for row tile `i`, zero-filling the overhang exactly
/// like the worker's scratch path.
fn row_block(data: &PaddedData, i: usize) -> Vec<f32> {
    let start = i * SPEC.r;
    let avail = data.n_pad.saturating_sub(start).min(SPEC.r);
    let mut xr = vec![0.0f32; SPEC.r * data.d_pad];
    xr[..avail * data.d_pad].copy_from_slice(data.row_block(start, avail));
    xr
}

#[test]
fn proved_tiles_are_exactly_zero_and_the_bound_is_a_true_lower_bound() {
    let mut proved_total = 0usize;
    let mut proved_sorted_blobs = 0usize;
    let mut tiles_sorted_blobs = 0usize;

    for (name, x) in cases() {
        let n = x.len() / SPEC.d;
        let data = PaddedData::new(&x, SPEC.d, &SPEC);
        let col_bounds = data.tile_bounds(SPEC.c);
        for kind in COMPACT {
            for ard in [false, true] {
                for radius in [0.5, 2.0] {
                    let h = hypers(ard);
                    let th = theta(&h);
                    let mut be = make_backend(kind, ard, radius);
                    let cut = be.support_cutoff(&th).expect("compact kernel must report a cutoff");
                    let eval = KernelEval::with_radius(kind, &h, radius);

                    for i in 0..n.div_ceil(SPEC.r) {
                        let true_rows = (n - i * SPEC.r).min(SPEC.r);
                        let rb = BBox::from_rows(&data.x, data.d_pad, i * SPEC.r, true_rows);
                        for j in 0..data.n_pad / SPEC.c {
                            let cb = col_bounds.tile(j);
                            let bound = rb.min_scaled_sq_dist(&cb, &cut.inv_ls);

                            // The bound is a true lower bound on every
                            // pair's scaled squared distance (f64, over
                            // the same f32 coordinates the tile path
                            // consumes).
                            let mut actual_min = f64::INFINITY;
                            for a in i * SPEC.r..i * SPEC.r + true_rows {
                                for b in j * SPEC.c..((j + 1) * SPEC.c).min(n) {
                                    let mut s = 0.0;
                                    for dim in 0..SPEC.d {
                                        let g = (data.x[a * SPEC.d + dim] as f64
                                            - data.x[b * SPEC.d + dim] as f64)
                                            * cut.inv_ls[dim];
                                        s += g * g;
                                    }
                                    actual_min = actual_min.min(s);
                                }
                            }
                            assert!(
                                bound <= actual_min * (1.0 + 1e-12) + 1e-300,
                                "{name} {kind:?} ard={ard} radius={radius} tile ({i},{j}): \
                                 bound {bound} exceeds the true min {actual_min}"
                            );

                            if !cut.proves_zero(bound) {
                                continue;
                            }
                            proved_total += 1;

                            // Soundness on the production path: the block
                            // the worker would have materialized is
                            // exactly +0.0 everywhere.
                            let xr = row_block(&data, i);
                            let xc = data.row_block(j * SPEC.c, SPEC.c);
                            let mut rho = vec![1.0f32; SPEC.r * SPEC.c];
                            be.materialize_tile(&xr, xc, &th, &mut rho).unwrap();
                            for (e, v) in rho.iter().enumerate() {
                                assert_eq!(
                                    v.to_bits(),
                                    0.0f32.to_bits(),
                                    "{name} {kind:?} ard={ard} radius={radius} tile ({i},{j}) \
                                     entry {e}: proved-zero tile materialized {v}"
                                );
                            }

                            // And on a dense f64 per-pair evaluation of
                            // every true pair.
                            for a in i * SPEC.r..i * SPEC.r + true_rows {
                                for b in j * SPEC.c..((j + 1) * SPEC.c).min(n) {
                                    let xa: Vec<f64> = (0..SPEC.d)
                                        .map(|dim| data.x[a * SPEC.d + dim] as f64)
                                        .collect();
                                    let xb: Vec<f64> = (0..SPEC.d)
                                        .map(|dim| data.x[b * SPEC.d + dim] as f64)
                                        .collect();
                                    let k = eval.eval(&xa, &xb);
                                    assert_eq!(
                                        k, 0.0,
                                        "{name} {kind:?} ard={ard} radius={radius}: proved tile \
                                         ({i},{j}) holds pair ({a},{b}) with k={k}"
                                    );
                                }
                            }

                            // Monotone under sub-splitting: every
                            // sub-range of the proved row block (down to
                            // single rows) is still proved, so no job
                            // split can resurrect this tile.
                            for lo in 0..true_rows {
                                for hi in lo + 1..=true_rows {
                                    let sub = BBox::from_rows(
                                        &data.x,
                                        data.d_pad,
                                        i * SPEC.r + lo,
                                        hi - lo,
                                    );
                                    let sb = sub.min_scaled_sq_dist(&cb, &cut.inv_ls);
                                    assert!(
                                        sb >= bound,
                                        "{name} {kind:?} tile ({i},{j}) rows [{lo},{hi}): \
                                         sub-box bound {sb} < parent bound {bound}"
                                    );
                                    assert!(cut.proves_zero(sb));
                                }
                            }

                            if name == "sorted-blobs" && !ard && radius == 0.5 {
                                proved_sorted_blobs += 1;
                            }
                        }
                    }
                    if name == "sorted-blobs" && !ard && radius == 0.5 && kind == COMPACT[0] {
                        tiles_sorted_blobs = n.div_ceil(SPEC.r) * (data.n_pad / SPEC.c);
                    }
                }
            }
        }
    }

    // Non-vacuity: the suite must actually exercise the skip path, and on
    // the canonical sorted-blobs layout the proof clears at least the
    // cross-blob half of the grid (the acceptance floor is 30%).
    assert!(proved_total > 0, "no tile was ever proved zero — the property test is vacuous");
    let per_kernel = proved_sorted_blobs / COMPACT.len();
    assert!(
        per_kernel * 10 >= tiles_sorted_blobs * 3,
        "sorted blobs at radius 0.5: only {per_kernel}/{tiles_sorted_blobs} tiles proved (< 30%)"
    );
}

#[test]
fn dense_kernels_never_report_a_cutoff_and_compact_always_do() {
    for kind in KernelKind::ALL {
        let be = make_backend(kind, false, 1.5);
        let cut = be.support_cutoff(&theta(&hypers(false)));
        assert_eq!(cut.is_some(), kind.is_compact(), "{kind:?}");
    }
}

#[test]
fn all_padding_row_blocks_prove_zero() {
    // A row block consisting entirely of padding rows has an empty bbox
    // (lo = +inf), which proves zero against any column tile: padding
    // outputs are discarded by the coordinator, so skipping them is sound
    // — and mandatory, or the skip-rate denominator would count tiles
    // that carry no information.
    let x: Vec<f64> = vec![0.5; 6 * SPEC.d];
    let data = PaddedData::new(&x, SPEC.d, &SPEC);
    let empty = BBox::from_rows(&data.x, data.d_pad, data.n_pad, 0);
    assert!(empty.is_empty());
    let be = make_backend(KernelKind::WendlandC2, false, 2.0);
    let cut = be.support_cutoff(&theta(&hypers(false))).unwrap();
    let cb = data.tile_bounds(SPEC.c).tile(0);
    assert!(cut.proves_zero(empty.min_scaled_sq_dist(&cb, &cut.inv_ls)));
}
