//! Kernel-block cache coherence: a cached operator must be *observably
//! identical* to a streaming one — bitwise-equal MVM results across worker
//! counts and partition shapes, correct invalidation when `set_hypers`
//! bumps the generation, and graceful streaming of whatever exceeds the
//! byte budget (including the unaligned edge sizes of partition_edge.rs).

use std::sync::Arc;

use exactgp::config::TransportKind;
use exactgp::exec::transport::subprocess::SubprocessOptions;
use exactgp::exec::transport::BackendSpec;
use exactgp::exec::{pool::DevicePool, PaddedData, PartitionedKernelOp, TileSpec};
use exactgp::kernels::{Hypers, KernelKind};
use exactgp::linalg::Mat;
use exactgp::metrics::Accounting;
use exactgp::partition::Plan;
use exactgp::solvers::BatchMvm;
use exactgp::util::rng::Rng;

const SPEC: TileSpec = TileSpec { r: 4, c: 8, t: 2, d: 3 };

fn hypers() -> Hypers {
    Hypers {
        log_lengthscales: vec![0.15],
        log_outputscale: 0.1,
        log_noise: (0.3f64).ln(),
    }
}

/// Pool on whichever transport `EXACTGP_TRANSPORT` selects (default
/// local) — the CI subprocess leg runs this whole suite, counters and
/// all, over real worker processes.
fn build_pool(workers: usize) -> Arc<DevicePool> {
    build_pool_with(workers, KernelKind::Matern32, 1.0)
}

fn build_pool_with(workers: usize, kernel: KernelKind, radius: f64) -> Arc<DevicePool> {
    let kind = TransportKind::from_env().unwrap_or(TransportKind::Local);
    let backend = BackendSpec::Native { kernel, ard: false, spec: SPEC, radius };
    let mut opts = SubprocessOptions::from_env();
    opts.worker_bin = Some(env!("CARGO_BIN_EXE_exactgp").into());
    Arc::new(DevicePool::with_transport(kind, workers, &backend, opts).unwrap())
}

fn build_op(
    x: &[f64],
    workers: usize,
    rows_per_partition: usize,
    cache_budget: usize,
) -> PartitionedKernelOp {
    let pool = build_pool(workers);
    let data = Arc::new(PaddedData::new(x, SPEC.d, &SPEC));
    let plan = Plan::with_rows(data.n_pad, data.n_pad, rows_per_partition);
    PartitionedKernelOp::square(
        data,
        pool,
        plan,
        SPEC,
        hypers(),
        Arc::new(Accounting::default()),
    )
    .with_cache_budget(cache_budget)
}

fn toy(n: usize) -> (Vec<f64>, Mat) {
    let mut rng = Rng::new(101, n as u64);
    let x: Vec<f64> = (0..n * SPEC.d).map(|_| rng.normal()).collect();
    let v = Mat::from_vec(n, SPEC.t, rng.normal_vec(n * SPEC.t));
    (x, v)
}

#[test]
fn cached_matches_streaming_bitwise_across_worker_counts() {
    // n = 45 deliberately misaligns with every tile dimension.
    let (x, v) = toy(45);
    let reference = build_op(&x, 1, usize::MAX / 2, 0).mvm(&v);
    for workers in [1usize, 2, 4] {
        for rpp in [SPEC.r, SPEC.r * 3, 1024] {
            let op = build_op(&x, workers, rpp, 64 << 20);
            let cold = op.mvm(&v);
            let warm = op.mvm(&v);
            // Bitwise: the cached gemm replays the exact f32 op sequence
            // of the streaming path, and the f64 tile traversal order is
            // unchanged, so even the last ulp must agree.
            assert_eq!(
                cold.data, reference.data,
                "cold cache != streaming (workers={workers} rpp={rpp})"
            );
            assert_eq!(
                warm.data, reference.data,
                "warm cache != streaming (workers={workers} rpp={rpp})"
            );
            let snap = op.acct.snapshot();
            assert!(snap.cache_fills > 0, "budget was granted but nothing cached");
            assert!(snap.cache_hits > 0, "second MVM never hit the cache");
        }
    }
}

#[test]
fn warm_mvm_serves_every_tile_from_cache() {
    let (x, v) = toy(64);
    let op = build_op(&x, 2, SPEC.r * 2, 64 << 20);
    let _ = op.mvm(&v);
    let fills = op.acct.snapshot().cache_fills;
    assert!(fills > 0);
    let before = op.acct.snapshot();
    let _ = op.mvm(&v);
    let delta = op.acct.snapshot().delta(&before);
    assert_eq!(delta.cache_fills, 0, "warm MVM re-materialized blocks");
    assert_eq!(delta.cache_hits, fills, "warm MVM must hit every cached tile");
}

#[test]
fn set_hypers_invalidates_stale_blocks() {
    let (x, v) = toy(40);
    let mut op = build_op(&x, 2, SPEC.r * 2, 64 << 20);
    let old = op.mvm(&v);
    let gen0 = op.hyper_gen;

    // Move the lengthscale: every cached rho block is now stale.
    let mut h2 = hypers();
    h2.log_lengthscales[0] = 0.6;
    op.set_hypers(h2.clone());
    assert!(op.hyper_gen > gen0, "set_hypers must bump the hyper generation");

    let before = op.acct.snapshot();
    let got = op.mvm(&v);
    let delta = op.acct.snapshot().delta(&before);
    assert!(delta.cache_fills > 0, "stale blocks were not refilled");

    // A streaming op built directly at the new hypers is the ground truth;
    // serving any stale-generation block would break this bitwise match.
    let mut fresh = build_op(&x, 1, usize::MAX / 2, 0);
    fresh.set_hypers(h2);
    let want = fresh.mvm(&v);
    assert_eq!(got.data, want.data, "cached MVM after set_hypers is stale");
    assert!(got.max_abs_diff(&old) > 1e-6, "hyper move should change results");
}

#[test]
fn append_rows_keeps_prior_blocks_and_matches_a_fresh_op_bitwise() {
    // Growing an op in place (online learning) is a cache event distinct
    // from a hyper move: the data generation bumps, blocks that were
    // fully in-bounds before the append survive it (the appended rows
    // cannot change them), and the grown op must be observably identical
    // to an op built from scratch over the concatenated rows.
    let (x, v0) = toy(40); // 40 aligns with r=4 and c=8: every block is full
    let mut rng = Rng::new(106, 0);
    let extra: Vec<f64> = (0..7 * SPEC.d).map(|_| rng.normal()).collect();
    let mut all = x.clone();
    all.extend_from_slice(&extra);
    let v1 = Mat::from_vec(47, SPEC.t, rng.normal_vec(47 * SPEC.t));

    let pool = build_pool(2);
    let base = Arc::new(PaddedData::new(&x, SPEC.d, &SPEC));
    let plan = Plan::with_rows(base.n_pad, base.n_pad, SPEC.r * 2);
    let mut op = PartitionedKernelOp::square(
        base.clone(),
        pool,
        plan,
        SPEC,
        hypers(),
        Arc::new(Accounting::default()),
    )
    .with_cache_budget(64 << 20);

    let _ = op.mvm(&v0); // warm the cache over the base rows
    let warmed = op.acct.snapshot();
    assert!(warmed.cache_fills > 0);
    let (h0, d0) = (op.hyper_gen, op.data_gen);

    let grown = Arc::new(PaddedData::append_from(&base, &all, SPEC.d, &SPEC));
    op.append_rows(grown);
    assert_eq!(op.hyper_gen, h0, "append must not invalidate hyper state");
    assert_eq!(op.data_gen, d0 + 1, "append must bump the data generation");
    assert_eq!(op.n_rows(), 47);

    let got = op.mvm(&v1);
    let after = op.acct.snapshot().delta(&warmed);
    // Retention: the base rows' blocks were full, so the first pass at
    // the new size serves them from cache and only fills blocks touching
    // the appended rows.
    assert!(after.cache_hits > 0, "append dropped the still-valid base blocks");
    assert!(after.cache_fills > 0, "blocks over the appended rows must be new fills");

    let fresh_data = Arc::new(PaddedData::new(&all, SPEC.d, &SPEC));
    let fresh_plan = Plan::with_rows(fresh_data.n_pad, fresh_data.n_pad, SPEC.r * 2);
    let fresh = PartitionedKernelOp::square(
        fresh_data,
        build_pool(2),
        fresh_plan,
        SPEC,
        hypers(),
        Arc::new(Accounting::default()),
    );
    assert_eq!(got.data, fresh.mvm(&v1).data, "grown op != fresh op over the same rows");

    // Steady state at the new size: a second pass is all hits again.
    let before = op.acct.snapshot();
    let again = op.mvm(&v1);
    let delta = op.acct.snapshot().delta(&before);
    assert_eq!(again.data, got.data);
    assert_eq!(delta.cache_fills, 0, "post-append warm pass re-materialized blocks");
    assert!(delta.cache_hits > 0);
}

#[test]
fn over_budget_datasets_stream_the_tail() {
    // Budget for exactly 3 correlation blocks; n = 45 needs
    // ceil(48/4) * ceil(48/8) = 72. Everything past the quota streams,
    // and the results stay bitwise-identical to full streaming.
    let (x, v) = toy(45);
    let block_bytes = SPEC.r * SPEC.c * 4;
    let reference = build_op(&x, 1, usize::MAX / 2, 0).mvm(&v);
    for workers in [1usize, 3] {
        let op = build_op(&x, workers, SPEC.r * 2, 3 * block_bytes);
        let cold = op.mvm(&v);
        let warm = op.mvm(&v);
        assert_eq!(cold.data, reference.data, "over-budget cold run diverged");
        assert_eq!(warm.data, reference.data, "over-budget warm run diverged");
        let snap = op.acct.snapshot();
        assert!(snap.cache_fills <= 3, "budget exceeded: {} fills", snap.cache_fills);
        assert!(snap.cache_fills > 0, "no blocks cached despite budget");
        assert_eq!(snap.cache_hits, snap.cache_fills, "each cached block hits once");
    }
}

#[test]
fn zero_budget_never_touches_the_cache() {
    let (x, v) = toy(33);
    let op = build_op(&x, 2, SPEC.r, 0);
    let _ = op.mvm(&v);
    let _ = op.mvm(&v);
    let snap = op.acct.snapshot();
    assert_eq!(snap.cache_fills, 0);
    assert_eq!(snap.cache_hits, 0);
}

/// Two tight d = 3 clusters, `sep` apart on the diagonal, pre-sorted so
/// every tile is pure one blob — the geometry under which a compact
/// kernel's bbox proof clears all cross-blob tiles.
fn blobs(n_per: usize, sep: f64) -> Vec<f64> {
    let mut rng = Rng::new(103, n_per as u64);
    let mut x = Vec::with_capacity(2 * n_per * SPEC.d);
    for blob in 0..2 {
        let center = blob as f64 * sep;
        for _ in 0..n_per * SPEC.d {
            x.push(center + 0.3 * rng.normal());
        }
    }
    x
}

/// A Wendland C2 op at support radius 2 with the skip decision pinned
/// explicitly (env-independent, so this suite can run under
/// `EXACTGP_FORCE_DENSE_TILES` sweeps without changing meaning).
fn build_compact_op(
    x: &[f64],
    workers: usize,
    rows_per_partition: usize,
    cache_budget: usize,
    force_dense: bool,
) -> PartitionedKernelOp {
    let pool = build_pool_with(workers, KernelKind::WendlandC2, 2.0);
    let data = Arc::new(PaddedData::new(x, SPEC.d, &SPEC));
    let plan = Plan::with_rows(data.n_pad, data.n_pad, rows_per_partition);
    PartitionedKernelOp::square(
        data,
        pool,
        plan,
        SPEC,
        hypers(),
        Arc::new(Accounting::default()),
    )
    .with_cache_budget(cache_budget)
    .with_force_dense(force_dense)
}

#[test]
fn skipped_tiles_consume_no_cache_quota_and_are_reproved_each_pass() {
    // Cache slots are a prefix of the *live* tile traversal: a proved-zero
    // tile never fills a slot, never hits, and never advances the slot
    // index. The skip proof itself is re-run on every pass (it is a pure
    // function of theta and the bboxes, never cached), so warm passes
    // report the same skip count as cold ones — and stay bitwise equal to
    // a force-dense op with the same budget.
    let x = blobs(24, 10.0);
    let mut rng = Rng::new(104, 0);
    let v = Mat::from_vec(48, SPEC.t, rng.normal_vec(48 * SPEC.t));

    let dense = build_compact_op(&x, 2, SPEC.r * 2, 64 << 20, true);
    let want_cold = dense.mvm(&v);
    let want_warm = dense.mvm(&v);
    assert_eq!(dense.acct.snapshot().tiles_skipped, 0);

    let op = build_compact_op(&x, 2, SPEC.r * 2, 64 << 20, false);
    let cold = op.mvm(&v);
    let s_cold = op.acct.snapshot();
    assert_eq!(cold.data, want_cold.data, "skip != dense on the cold pass");
    assert!(s_cold.tiles_skipped > 0, "cross-blob tiles were not skipped");
    assert!(s_cold.cache_fills > 0, "live tiles never filled the cache");
    // Only live tiles occupy slots: fills + skips account for every
    // candidate tile of the cold pass.
    assert_eq!(s_cold.cache_fills + s_cold.tiles_skipped, s_cold.tiles_total);

    let warm = op.mvm(&v);
    let d_warm = op.acct.snapshot().delta(&s_cold);
    assert_eq!(warm.data, want_warm.data, "skip != dense on the warm pass");
    assert_eq!(d_warm.cache_fills, 0, "warm pass re-materialized live tiles");
    assert_eq!(d_warm.cache_hits, s_cold.cache_fills, "warm pass must hit every live slot");
    assert_eq!(d_warm.tiles_skipped, s_cold.tiles_skipped, "skip proof not re-run on warm pass");
}

#[test]
fn set_hypers_reproves_skips_and_invalidates_compact_blocks() {
    // A lengthscale move flips which tiles the proof clears *and* makes
    // every cached block stale. After set_hypers the op must refill (not
    // replay) and still match a fresh force-dense op bitwise — in both
    // directions of the flip.
    let x = blobs(24, 10.0);
    let mut rng = Rng::new(105, 0);
    let v = Mat::from_vec(48, SPEC.t, rng.normal_vec(48 * SPEC.t));
    let mut wide = hypers();
    wide.log_lengthscales[0] = 2.5; // scaled blob gap drops below the radius

    let mut op = build_compact_op(&x, 2, SPEC.r * 2, 64 << 20, false);
    let mut dense = build_compact_op(&x, 2, SPEC.r * 2, 64 << 20, true);
    assert_eq!(op.mvm(&v).data, dense.mvm(&v).data);
    let s1 = op.acct.snapshot();
    assert!(s1.tiles_skipped > 0);

    op.set_hypers(wide.clone());
    dense.set_hypers(wide);
    assert_eq!(op.mvm(&v).data, dense.mvm(&v).data, "stale block served after flip to live");
    let s2 = op.acct.snapshot();
    assert_eq!(s2.delta(&s1).tiles_skipped, 0, "wide lengthscale must not skip");
    assert!(s2.delta(&s1).cache_fills > 0, "flipped-live tiles never refilled");

    op.set_hypers(hypers());
    dense.set_hypers(hypers());
    assert_eq!(op.mvm(&v).data, dense.mvm(&v).data, "stale block served after flip back");
    assert!(op.acct.snapshot().delta(&s2).tiles_skipped > 0, "tiles did not flip back");
}

#[test]
fn gradient_mvms_share_the_pool_without_corrupting_cached_results() {
    // Interleave cached MVMs with (streaming) gradient MVMs on the same
    // pool: the gradient jobs must leave the cached blocks untouched.
    let (x, v) = toy(40);
    let op = build_op(&x, 2, SPEC.r * 2, 64 << 20);
    let first = op.mvm(&v);
    let _ = op.apply_grads(&v);
    let before = op.acct.snapshot();
    let second = op.mvm(&v);
    let delta = op.acct.snapshot().delta(&before);
    assert_eq!(first.data, second.data);
    assert_eq!(delta.cache_fills, 0, "gradient jobs evicted cached blocks");
    assert!(delta.cache_hits > 0);
}
