//! Serving-tier acceptance test (the PR's end-to-end contract): a real
//! TCP server over two checkpointed models under a one-model memory
//! budget, asserting
//!
//! (a) **bitwise parity** — every served answer equals a direct
//!     `ExactGp::predict` on the same checkpoint, across LRU
//!     evict/reload churn;
//! (b) **explicit sheds** — overload past the admission cap produces a
//!     retryable shed reply, never silent queueing, and the retry
//!     succeeds once capacity frees;
//! (c) **honest books** — the `stats` verb's per-model
//!     load/evict/shed/request counters match the scenario exactly.

mod server_common;

use std::sync::Arc;
use std::time::Duration;

use exactgp::server::{Client, PredictOutcome, Registry, Server};
use exactgp::util::json::Json;
use server_common::{fixture, one_model_budget, specs, RefModel};

fn answer(cl: &mut Client, m: &RefModel, qi: usize) -> exactgp::gp::Predictions {
    match cl.predict(m.name, m.point(qi)).unwrap() {
        PredictOutcome::Answer(p) => p,
        other => panic!("expected an answer for {}[{qi}], got {other:?}", m.name),
    }
}

fn assert_bitwise(p: &exactgp::gp::Predictions, m: &RefModel, qi: usize) {
    assert_eq!(
        p.mean[0].to_bits(),
        m.mean[qi].to_bits(),
        "served mean for {}[{qi}] is not bitwise the direct predict",
        m.name
    );
    assert_eq!(
        p.var[0].to_bits(),
        m.var[qi].to_bits(),
        "served var for {}[{qi}] is not bitwise the direct predict",
        m.name
    );
    assert_eq!(p.noise.to_bits(), m.noise.to_bits());
}

fn counter(stats: &Json, model: &str, key: &str) -> u64 {
    stats.req("models").unwrap().req(model).unwrap().req_f64(key).unwrap() as u64
}

#[test]
fn tcp_tier_serves_two_models_with_parity_sheds_and_honest_stats() {
    let fx = fixture();
    let (a, b) = (&fx.models[0], &fx.models[1]);

    let mut cfg = fx.cfg.clone();
    cfg.server_listen = "127.0.0.1:0".into();
    cfg.server_max_inflight = 1;
    cfg.server_max_inflight_per_model = 1;
    // Deterministic overload: with a huge batch and a long deadline, one
    // in-flight predict holds its admission permit for ~500ms, so a
    // second request inside that window *must* shed under cap 1.
    cfg.serve_batch = 512;
    cfg.serve_max_delay_ms = 500.0;

    let registry =
        Arc::new(Registry::with_budget_bytes(&cfg, &specs(fx), one_model_budget(fx)).unwrap());
    let server = Server::start_with_registry(&cfg, registry.clone()).unwrap();
    let addr = server.addr();

    // (a) Parity through churn: A twice, then B (evicts A), then A again
    // (evicts B, reloads A) — five answers, all bitwise.
    let mut cl = Client::connect(addr).unwrap();
    assert_bitwise(&answer(&mut cl, a, 0), a, 0);
    assert_bitwise(&answer(&mut cl, a, 1), a, 1);
    assert!(registry.is_resident(a.name));
    assert_bitwise(&answer(&mut cl, b, 0), b, 0);
    assert_bitwise(&answer(&mut cl, b, 1), b, 1);
    assert!(!registry.is_resident(a.name), "B must have evicted A");
    assert_bitwise(&answer(&mut cl, a, 2), a, 2);
    assert!(!registry.is_resident(b.name), "A's reload must have evicted B");

    // (b) Explicit shed under overload, then success on retry.
    std::thread::scope(|scope| {
        let holder = scope.spawn(|| {
            let mut c1 = Client::connect(addr).unwrap();
            answer(&mut c1, a, 0)
        });
        // Let the holder's request win the only permit (it then sits in
        // the coalescing window for ~500ms)...
        std::thread::sleep(Duration::from_millis(250));
        let mut c2 = Client::connect(addr).unwrap();
        match c2.predict(a.name, a.point(1)).unwrap() {
            PredictOutcome::Shed(msg) => {
                assert!(msg.contains("overloaded"), "shed reply should say why: {msg}")
            }
            other => panic!("second in-flight request past cap 1 must shed, got {other:?}"),
        }
        // ...and once the holder's reply lands, capacity is back.
        assert_bitwise(&holder.join().unwrap(), a, 0);
        assert_bitwise(&answer(&mut c2, a, 1), a, 1);
    });

    // (c) The books match the scenario exactly.
    let stats = cl.stats().unwrap();
    assert_eq!(stats.req("ok").unwrap().as_bool(), Some(true));
    assert_eq!(stats.req("inflight").unwrap().as_f64(), Some(0.0));
    // A: 3 parity answers + holder + shed + retry = 6 requests, 5 points.
    assert_eq!(counter(&stats, a.name, "requests"), 6);
    assert_eq!(counter(&stats, a.name, "points"), 5);
    assert_eq!(counter(&stats, a.name, "sheds"), 1);
    assert_eq!(counter(&stats, a.name, "errors"), 0);
    assert_eq!(counter(&stats, a.name, "loads"), 2);
    assert_eq!(counter(&stats, a.name, "evictions"), 1);
    // B: 2 parity answers; evicted once when A came back.
    assert_eq!(counter(&stats, b.name, "requests"), 2);
    assert_eq!(counter(&stats, b.name, "points"), 2);
    assert_eq!(counter(&stats, b.name, "sheds"), 0);
    assert_eq!(counter(&stats, b.name, "loads"), 1);
    assert_eq!(counter(&stats, b.name, "evictions"), 1);
    // Residency never exceeded the one-model budget.
    let resident = stats.req("resident_bytes_est").unwrap().as_f64().unwrap();
    let budget = stats.req("budget_bytes").unwrap().as_f64().unwrap();
    assert!(resident <= budget, "resident {resident} over budget {budget}");

    // The models verb agrees about who is resident right now.
    let models = cl.models().unwrap();
    let rows = models.req("models").unwrap().as_arr().unwrap().clone();
    for row in &rows {
        let name = row.req_str("name").unwrap();
        let resident = row.req("resident").unwrap().as_bool().unwrap();
        assert_eq!(resident, name == a.name, "{name} residency wrong");
    }

    drop(cl);
    server.shutdown();
}

/// Malformed queries are rejected before admission: they consume no
/// capacity, reply non-retryable, and leave the books clean.
#[test]
fn malformed_queries_never_reach_admission() {
    let fx = fixture();
    let a = &fx.models[0];
    let mut cfg = fx.cfg.clone();
    cfg.server_listen = "127.0.0.1:0".into();

    let registry =
        Arc::new(Registry::with_budget_bytes(&cfg, &specs(fx), one_model_budget(fx)).unwrap());
    let server = Server::start_with_registry(&cfg, registry.clone()).unwrap();
    let mut cl = Client::connect(server.addr()).unwrap();

    // Wrong arity: d+1 values cannot be a (m, d) query.
    match cl.predict(a.name, vec![0.0; a.d + 1]).unwrap() {
        PredictOutcome::Failed(msg) => {
            assert!(msg.contains("multiple of d"), "{msg}")
        }
        other => panic!("expected a permanent failure, got {other:?}"),
    }
    // Rejected before load: the model never became resident, and the
    // request was counted but shed/error-free capacity-wise.
    assert!(!registry.is_resident(a.name), "malformed query must not trigger a load");
    let stats = cl.stats().unwrap();
    assert_eq!(counter(&stats, a.name, "requests"), 1);
    assert_eq!(counter(&stats, a.name, "points"), 0);
    assert_eq!(counter(&stats, a.name, "sheds"), 0);
    assert_eq!(counter(&stats, a.name, "loads"), 0);

    drop(cl);
    server.shutdown();
}
