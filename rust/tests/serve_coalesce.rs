//! Coalescing serve loop: concurrent (and queued) single-point queries
//! must return **bitwise** the answers of one batched `predict`, while the
//! dispatch counters prove the loop actually coalesced them into batches
//! instead of serving point by point.

use std::time::Duration;

use exactgp::config::{Backend, Config};
use exactgp::coordinator::{self, serve};
use exactgp::data::synthetic::Scale;
use exactgp::gp::exact::{ExactGp, Recipe};
use exactgp::util::rng::Rng;

fn served_model(cap: usize) -> (ExactGp, exactgp::data::Dataset) {
    let mut cfg = Config::default();
    cfg.backend = Backend::Native;
    cfg.scale = Scale { train_cap: cap };
    cfg.workers = 2;
    cfg.precond_rank = 12;
    cfg.variance_rank = 16;
    let ds = coordinator::load_dataset(&cfg, "bike", 0).unwrap();
    let (pool, spec) = coordinator::make_pool(&cfg, ds.d).unwrap();
    let mut rng = Rng::new(21, 0);
    let mut gp = ExactGp::new(&cfg, cfg.kernel, &ds, pool, spec);
    gp.train(Recipe { pretrain: false, adam_steps: 1 }, &mut rng).unwrap();
    gp.precompute(&mut rng).unwrap();
    (gp, ds)
}

#[test]
fn queued_single_point_queries_coalesce_and_match_batched() {
    let (gp, ds) = served_model(192);
    let d = ds.d;
    let m = ds.n_test().min(40);
    let batched = gp.predict(&ds.test_x[..m * d]).unwrap();

    // Deterministic coalescing: queue all queries first, then run the
    // loop. 40 single-point queries at batch_points=16 must produce
    // exactly ceil(40/16)=3 dispatches — two full flushes and one
    // shutdown-drain flush — never 40 per-point dispatches.
    let (handle, rx) = serve::channel(gp.dim());
    let mut replies = Vec::with_capacity(m);
    for qi in 0..m {
        let x = ds.test_x[qi * d..(qi + 1) * d].to_vec();
        replies.push(handle.submit(x).unwrap());
    }
    drop(handle);
    let before = gp.accounting().snapshot();
    let stats = serve::run(&gp, rx, 16, Duration::from_millis(50)).unwrap();

    let full = (m / 16) as u64; // full flushes
    let drain = u64::from(m % 16 != 0); // shutdown-drain flush for the rest
    assert_eq!(stats.requests, m as u64);
    assert_eq!(stats.points, m as u64);
    assert_eq!(stats.batches, full + drain, "expected ceil({m}/16) dispatches: {stats:?}");
    assert_eq!(stats.flush_full, full, "{stats:?}");
    assert_eq!(stats.flush_deadline, drain, "shutdown drain flush: {stats:?}");
    assert!(stats.batches < stats.requests, "no coalescing happened: {stats:?}");

    // The same numbers land in the model's Accounting.
    let delta = gp.accounting().snapshot().delta(&before);
    assert_eq!(delta.serve_requests, m as u64);
    assert_eq!(delta.serve_batches, full + drain);
    assert_eq!(delta.serve_flush_full, full);
    assert_eq!(delta.serve_flush_deadline, drain);

    // Bitwise parity with the batched predict, reply by reply.
    for (qi, rx) in replies.into_iter().enumerate() {
        let p = rx.recv().unwrap().unwrap();
        assert_eq!(p.mean.len(), 1);
        assert_eq!(
            p.mean[0].to_bits(),
            batched.mean[qi].to_bits(),
            "mean[{qi}] diverged under coalescing"
        );
        assert_eq!(
            p.var[0].to_bits(),
            batched.var[qi].to_bits(),
            "var[{qi}] diverged under coalescing"
        );
        assert_eq!(p.noise.to_bits(), batched.noise.to_bits());
    }
}

#[test]
fn concurrent_clients_get_correct_answers() {
    let (gp, ds) = served_model(160);
    let d = ds.d;
    let m = ds.n_test().min(24);
    let batched = gp.predict(&ds.test_x[..m * d]).unwrap();
    let test_x = std::sync::Arc::new(ds.test_x.clone());

    let (handle, rx) = serve::channel(gp.dim());
    let clients = 4;
    let per_client = m / clients;
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let handle = handle.clone();
            let test_x = test_x.clone();
            std::thread::spawn(move || {
                // Closed loop: each query waits for its reply (blocking
                // `query`), so the loop's deadline path gets exercised.
                let mut out = Vec::new();
                for k in 0..per_client {
                    let qi = c * per_client + k;
                    let p = handle.query(test_x[qi * d..(qi + 1) * d].to_vec()).unwrap();
                    out.push((qi, p.mean[0], p.var[0]));
                }
                out
            })
        })
        .collect();
    // A multi-point query rides along with the single-point traffic and
    // is never split across dispatches.
    let multi_rx = handle.submit(ds.test_x[..3 * d].to_vec()).unwrap();
    drop(handle);

    let stats = serve::run(&gp, rx, 8, Duration::from_millis(5)).unwrap();
    assert_eq!(stats.requests, (clients * per_client + 1) as u64);
    assert_eq!(stats.points, (clients * per_client + 3) as u64);

    let multi = multi_rx.recv().unwrap().unwrap();
    assert_eq!(multi.mean.len(), 3);
    for i in 0..3 {
        assert_eq!(multi.mean[i].to_bits(), batched.mean[i].to_bits());
        assert_eq!(multi.var[i].to_bits(), batched.var[i].to_bits());
    }
    for th in threads {
        for (qi, mean, var) in th.join().unwrap() {
            assert_eq!(mean.to_bits(), batched.mean[qi].to_bits(), "mean[{qi}]");
            assert_eq!(var.to_bits(), batched.var[qi].to_bits(), "var[{qi}]");
        }
    }
}

/// Regression: a failed dispatch used to kill the whole loop, silently
/// dropping every other client's pending and future queries. Now the
/// poisoned batch's waiters get the error reply and serving continues.
#[test]
fn failed_dispatch_poisons_only_its_batch() {
    use exactgp::coordinator::serve::ServeOptions;
    use exactgp::gp::Predictions;
    use exactgp::metrics::Accounting;
    use std::sync::Arc;

    let d = 2;
    let (handle, rx) = serve::channel(d);
    // Pre-queued so batch membership is deterministic at batch_points=1:
    // three dispatches, the middle one poisoned.
    let r1 = handle.submit(vec![1.0, 1.0]).unwrap();
    let r2 = handle.submit(vec![666.0, 0.0]).unwrap();
    let r3 = handle.submit(vec![2.0, 2.0]).unwrap();
    drop(handle);

    let acct = Arc::new(Accounting::default());
    let opts = ServeOptions {
        max_consecutive_failures: 3,
        ..ServeOptions::new(1, Duration::ZERO)
    };
    let stats = serve::run_with_dispatch(d, acct.clone(), rx, &opts, |xs| {
        if xs.contains(&666.0) {
            anyhow::bail!("poisoned batch");
        }
        let m = xs.len() / d;
        Ok(Predictions { mean: vec![0.5; m], var: vec![0.25; m], noise: 0.1 })
    })
    .unwrap();

    assert!(r1.recv().unwrap().is_ok());
    let err = r2.recv().unwrap().unwrap_err();
    assert!(err.contains("poisoned"), "waiters must see the dispatch error: {err}");
    assert!(
        r3.recv().unwrap().is_ok(),
        "a failed batch must not take down batches after it"
    );
    assert_eq!(stats.batches, 3);
    assert_eq!(stats.dispatch_failures, 1);
    assert_eq!(acct.snapshot().serve_dispatch_failures, 1);
}

/// A model whose *every* dispatch fails must not burn queries forever:
/// after the consecutive-failure cap the loop returns an error, and the
/// waiters it did reach all received explicit error replies first.
#[test]
fn persistent_dispatch_failure_ends_the_loop_at_the_cap() {
    use exactgp::coordinator::serve::ServeOptions;
    use exactgp::metrics::Accounting;
    use std::sync::Arc;

    let d = 1;
    let (handle, rx) = serve::channel(d);
    let replies: Vec<_> =
        (0..5).map(|i| handle.submit(vec![i as f64]).unwrap()).collect();
    drop(handle);

    let acct = Arc::new(Accounting::default());
    let opts = ServeOptions {
        max_consecutive_failures: 3,
        ..ServeOptions::new(1, Duration::ZERO)
    };
    let err = serve::run_with_dispatch(d, acct.clone(), rx, &opts, |_| {
        anyhow::bail!("backend gone")
    })
    .unwrap_err();
    assert!(format!("{err}").contains("consecutive"), "{err}");

    // Exactly the cap's worth of batches were dispatched and answered
    // with explicit errors; the rest were dropped when the loop died
    // (their recv errors — no silent hang).
    let (mut errored, mut dropped) = (0, 0);
    for r in replies {
        match r.recv() {
            Ok(Err(e)) => {
                assert!(e.contains("backend gone"), "{e}");
                errored += 1;
            }
            Err(_) => dropped += 1,
            Ok(Ok(_)) => panic!("no dispatch can have succeeded"),
        }
    }
    assert_eq!(errored, 3);
    assert_eq!(dropped, 2);
    assert_eq!(acct.snapshot().serve_dispatch_failures, 3);
}

/// The `serve.dispatch` fault seam fails exactly one scripted dispatch:
/// its waiter sees the injected error, every other query is answered, and
/// the failure is accounted like any backend error — the deterministic
/// handle the fault-injection harness needs on the serving path.
#[test]
fn injected_dispatch_fault_fails_one_batch_and_serving_continues() {
    use exactgp::coordinator::serve::ServeOptions;
    use exactgp::faults::FaultPlan;
    use exactgp::gp::Predictions;
    use exactgp::metrics::Accounting;
    use std::sync::Arc;

    let d = 1;
    let (handle, rx) = serve::channel(d);
    let replies: Vec<_> =
        (0..4).map(|i| handle.submit(vec![i as f64]).unwrap()).collect();
    drop(handle);

    let acct = Arc::new(Accounting::default());
    let opts = ServeOptions {
        plan: Arc::new(FaultPlan::parse("serve.dispatch:2").unwrap()),
        ..ServeOptions::new(1, Duration::ZERO)
    };
    let stats = serve::run_with_dispatch(d, acct.clone(), rx, &opts, |xs| {
        let m = xs.len() / d;
        Ok(Predictions { mean: vec![1.0; m], var: vec![2.0; m], noise: 0.1 })
    })
    .unwrap();

    for (i, r) in replies.into_iter().enumerate() {
        match r.recv().unwrap() {
            Ok(_) => assert_ne!(i, 1, "the 2nd dispatch was armed to fail"),
            Err(e) => {
                assert_eq!(i, 1, "only the armed dispatch may fail: {e}");
                assert!(e.contains("serve.dispatch"), "{e}");
            }
        }
    }
    assert_eq!(stats.batches, 4);
    assert_eq!(stats.dispatch_failures, 1);
    assert_eq!(acct.snapshot().serve_dispatch_failures, 1);
}
