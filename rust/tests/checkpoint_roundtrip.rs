//! Checkpoint round-trip parity (the durable-model contract):
//!
//! * `save` → `load` in a fresh model context reproduces predictions
//!   **bitwise** across prediction chunk sizes and worker counts;
//! * a loaded model performs zero solver work — no mBCG solve, no
//!   Lanczos pass, no preconditioner build — before its first predict;
//! * corrupt or tampered checkpoints are rejected with a clear error,
//!   never loaded into a model that would serve wrong numbers.

use exactgp::config::{Backend, Config};
use exactgp::coordinator;
use exactgp::data::synthetic::Scale;
use exactgp::gp::exact::{ExactGp, Recipe};
use exactgp::util::rng::Rng;

fn base_cfg(workers: usize, cap: usize) -> Config {
    let mut cfg = Config::default();
    cfg.backend = Backend::Native;
    cfg.scale = Scale { train_cap: cap };
    cfg.workers = workers;
    cfg.pretrain_subset = 64;
    cfg.pretrain_lbfgs_steps = 2;
    cfg.pretrain_adam_steps = 2;
    cfg.finetune_adam_steps = 2;
    cfg.precond_rank = 16;
    cfg.variance_rank = 24;
    cfg
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("exactgp_it_{tag}_{}", std::process::id()))
}

fn trained_model(cfg: &Config, name: &str) -> (ExactGp, exactgp::data::Dataset) {
    let ds = coordinator::load_dataset(cfg, name, 0).unwrap();
    let (pool, spec) = coordinator::make_pool(cfg, ds.d).unwrap();
    let mut rng = Rng::new(11, 0);
    let mut gp = ExactGp::new(cfg, cfg.kernel, &ds, pool, spec);
    gp.train(Recipe::paper_default(cfg), &mut rng).unwrap();
    gp.precompute(&mut rng).unwrap();
    (gp, ds)
}

#[test]
fn save_load_is_bitwise_identical_across_chunks_and_workers() {
    let cfg0 = base_cfg(2, 320);
    let (gp, ds) = trained_model(&cfg0, "bike");
    let want = gp.predict(&ds.test_x).unwrap();

    let dir = tmp_dir("roundtrip");
    let _ = std::fs::remove_dir_all(&dir);
    gp.save(&dir, &ds).unwrap();
    assert!(exactgp::runtime::checkpoint::exists(&dir));

    for workers in [1usize, 3] {
        for chunk in [0usize, 7, 64] {
            let mut cfg = base_cfg(workers, 320);
            cfg.predict_chunk = chunk;
            let (gp2, ds2) = coordinator::load_model(&cfg, &dir).unwrap();

            // The restored dataset carries the full pipeline + test split.
            assert_eq!(ds2.test_x, ds.test_x);
            assert_eq!(ds2.name, ds.name);

            // Zero solver work at startup — the accounting counters are
            // the proof serving relies on.
            let snap = gp2.accounting().snapshot();
            assert_eq!(snap.mbcg_solves, 0, "load ran an mBCG solve");
            assert_eq!(snap.lanczos_passes, 0, "load ran a Lanczos pass");
            assert_eq!(snap.precond_builds, 0, "load built a preconditioner");

            let got = gp2.predict(&ds2.test_x).unwrap();
            assert_eq!(got.mean.len(), want.mean.len());
            for i in 0..want.mean.len() {
                assert_eq!(
                    got.mean[i].to_bits(),
                    want.mean[i].to_bits(),
                    "mean[{i}] differs (workers={workers}, chunk={chunk})"
                );
                assert_eq!(
                    got.var[i].to_bits(),
                    want.var[i].to_bits(),
                    "var[{i}] differs (workers={workers}, chunk={chunk})"
                );
            }
            assert_eq!(got.noise.to_bits(), want.noise.to_bits());
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn save_requires_a_prediction_cache() {
    let cfg = base_cfg(1, 128);
    let ds = coordinator::load_dataset(&cfg, "bike", 0).unwrap();
    let (pool, spec) = coordinator::make_pool(&cfg, ds.d).unwrap();
    let gp = ExactGp::new(&cfg, cfg.kernel, &ds, pool, spec);
    let dir = tmp_dir("nocache");
    let err = gp.save(&dir, &ds).unwrap_err();
    assert!(format!("{err}").contains("precompute"), "{err}");
    assert!(!dir.exists(), "a partial checkpoint was written");
}

#[test]
fn tampered_checkpoint_refuses_to_load() {
    let cfg = base_cfg(1, 128);
    let (gp, ds) = trained_model(&cfg, "elevators");
    let dir = tmp_dir("tamper");
    let _ = std::fs::remove_dir_all(&dir);
    gp.save(&dir, &ds).unwrap();

    // Flip one byte of the prediction cache: load must fail on the
    // checksum, not serve a silently corrupted model.
    let file = dir.join("pred_rhs.bin");
    let mut bytes = std::fs::read(&file).unwrap();
    bytes[17] ^= 0x20;
    std::fs::write(&file, &bytes).unwrap();
    let err = format!("{:#}", coordinator::load_model(&cfg, &dir).unwrap_err());
    assert!(err.contains("checksum"), "{err}");

    // Missing sidecar: clear error, not a panic.
    std::fs::remove_file(&file).unwrap();
    let err = format!("{:#}", coordinator::load_model(&cfg, &dir).unwrap_err());
    assert!(err.contains("pred_rhs"), "{err}");

    let _ = std::fs::remove_dir_all(&dir);
}
