//! Shared fixture for the serving-tier integration tests: two small
//! trained-and-checkpointed models ("bike" and "elevators") plus their
//! direct-predict reference answers, built once per test process.
//!
//! Not a test crate itself — `tests/server_registry.rs` and
//! `tests/server_e2e.rs` pull it in with `mod server_common;`.

#![allow(dead_code)] // each including crate uses a different subset

use std::path::PathBuf;
use std::sync::OnceLock;

use exactgp::config::{Backend, Config};
use exactgp::coordinator;
use exactgp::data::synthetic::Scale;
use exactgp::gp::exact::{ExactGp, Recipe};
use exactgp::util::rng::Rng;

/// One checkpointed model plus its ground truth: the first `q` test
/// points and what a direct `ExactGp::predict` answers for them.
pub struct RefModel {
    /// Registry name (also the dataset name).
    pub name: &'static str,
    /// Checkpoint directory.
    pub dir: PathBuf,
    /// Feature dimensionality.
    pub d: usize,
    /// Flat (q, d) query points.
    pub x: Vec<f64>,
    /// Direct-predict means for `x`.
    pub mean: Vec<f64>,
    /// Direct-predict variances for `x`.
    pub var: Vec<f64>,
    /// Direct-predict noise.
    pub noise: f64,
    /// `checkpoint::peek` resident-bytes estimate.
    pub bytes: u64,
}

impl RefModel {
    /// The `qi`-th query point, flat.
    pub fn point(&self, qi: usize) -> Vec<f64> {
        self.x[qi * self.d..(qi + 1) * self.d].to_vec()
    }

    /// Number of reference points.
    pub fn points(&self) -> usize {
        self.mean.len()
    }
}

/// The fixture: a serving config and two reference models.
pub struct Fixture {
    /// Serving-side config (native backend, small serve batches).
    pub cfg: Config,
    /// `[bike, elevators]`.
    pub models: Vec<RefModel>,
}

/// The config every serving-tier test starts from.
pub fn serve_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.backend = Backend::Native;
    cfg.workers = 2;
    cfg.precond_rank = 12;
    cfg.variance_rank = 16;
    cfg.serve_batch = 16;
    cfg.serve_max_delay_ms = 5.0;
    cfg
}

static FIXTURE: OnceLock<Fixture> = OnceLock::new();

/// Build (once) and return the fixture.
pub fn fixture() -> &'static Fixture {
    FIXTURE.get_or_init(build)
}

fn build() -> Fixture {
    let specs: [(&'static str, usize); 2] = [("bike", 192), ("elevators", 160)];
    let mut models = Vec::new();
    for (name, cap) in specs {
        let mut cfg = serve_cfg();
        cfg.scale = Scale { train_cap: cap };
        let dir = std::env::temp_dir()
            .join(format!("exactgp_srv_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let ds = coordinator::load_dataset(&cfg, name, 0).unwrap();
        let (pool, spec) = coordinator::make_pool(&cfg, ds.d).unwrap();
        let mut rng = Rng::new(21, 0);
        let mut gp = ExactGp::new(&cfg, cfg.kernel, &ds, pool, spec);
        gp.train(Recipe { pretrain: false, adam_steps: 1 }, &mut rng).unwrap();
        gp.precompute(&mut rng).unwrap();
        gp.save(&dir, &ds).unwrap();

        let q = ds.n_test().min(24);
        assert!(q > 0, "{name} has no test split");
        let x = ds.test_x[..q * ds.d].to_vec();
        let p = gp.predict(&x).unwrap();
        let bytes = exactgp::runtime::checkpoint::peek(&dir).unwrap().resident_bytes;
        models.push(RefModel {
            name,
            dir,
            d: ds.d,
            x,
            mean: p.mean,
            var: p.var,
            noise: p.noise,
            bytes,
        });
    }
    Fixture { cfg: serve_cfg(), models }
}

/// `(name, dir)` specs for registering both fixture models.
pub fn specs(fx: &Fixture) -> Vec<(String, PathBuf)> {
    fx.models.iter().map(|m| (m.name.to_string(), m.dir.clone())).collect()
}

/// A budget that fits either model alone but never both.
pub fn one_model_budget(fx: &Fixture) -> u64 {
    let (a, b) = (fx.models[0].bytes, fx.models[1].bytes);
    assert!(a + b > a.max(b), "degenerate fixture sizes");
    a.max(b)
}
