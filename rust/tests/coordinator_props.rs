//! Property tests over coordinator invariants (custom quickcheck harness;
//! proptest is not in the offline dependency closure).

use std::sync::Arc;

use exactgp::exec::{native::NativeBackend, pool::DevicePool, BackendFactory, PaddedData,
                    PartitionedKernelOp, TileBackend, TileSpec};
use exactgp::kernels::{Hypers, KernelKind};
use exactgp::linalg::Mat;
use exactgp::metrics::Accounting;
use exactgp::partition::Plan;
use exactgp::solvers::BatchMvm;
use exactgp::util::quickcheck::check;

fn native_pool(spec: TileSpec, workers: usize) -> Arc<DevicePool> {
    let factory: BackendFactory = Arc::new(move |_| {
        Ok(Box::new(NativeBackend::new(KernelKind::Matern32, false, spec))
            as Box<dyn TileBackend>)
    });
    Arc::new(DevicePool::new(workers, factory).unwrap())
}

#[test]
fn prop_partition_plans_cover_disjointly() {
    check("plan-cover", 100, |g| {
        let n = 1 + g.rng.below(100_000);
        let budget = 1 << (10 + g.rng.below(16));
        let plan = Plan::with_memory_budget(n, n, budget, 16, 8);
        let mut next = 0;
        for p in &plan.partitions {
            if p.start != next || p.is_empty() {
                return Err(format!("bad partition at {}", p.start));
            }
            next = p.end;
        }
        if next != n {
            return Err(format!("cover ends at {next} != {n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_mvm_invariant_to_workers_and_partitioning() {
    // The coordinator's core routing invariant: the answer never depends
    // on how work is distributed.
    let spec = TileSpec { r: 4, c: 8, t: 2, d: 2 };
    check("mvm-routing-invariance", 12, |g| {
        let n = 5 + g.rng.below(60);
        let x: Vec<f64> = (0..n * 2).map(|_| g.rng.normal()).collect();
        let v = Mat::from_vec(n, 2, g.rng.normal_vec(n * 2));
        let hypers = Hypers::default_init(None);
        let mut outs: Vec<Mat> = Vec::new();
        for (workers, rpp_tiles) in [(1, 1), (2, 2), (3, 1), (4, 4)] {
            let data = Arc::new(PaddedData::new(&x, 2, &spec));
            let plan = Plan::with_rows(data.n_pad, data.n_pad, spec.r * rpp_tiles);
            let op = PartitionedKernelOp::square(
                data,
                native_pool(spec, workers),
                plan,
                spec,
                hypers.clone(),
                Arc::new(Accounting::default()),
            );
            outs.push(op.mvm(&v));
        }
        for o in &outs[1..] {
            if o.max_abs_diff(&outs[0]) > 1e-10 {
                return Err(format!("diff {}", o.max_abs_diff(&outs[0])));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mvm_linear_in_rhs() {
    // K(aV1 + bV2) == a K V1 + b K V2 — exercised through the whole
    // padding/chunking/dispatch stack.
    let spec = TileSpec { r: 4, c: 8, t: 2, d: 3 };
    check("mvm-linearity", 10, |g| {
        let n = 6 + g.rng.below(40);
        let x: Vec<f64> = (0..n * 3).map(|_| g.rng.normal()).collect();
        let data = Arc::new(PaddedData::new(&x, 3, &spec));
        let plan = Plan::with_rows(data.n_pad, data.n_pad, spec.r);
        let op = PartitionedKernelOp::square(
            data,
            native_pool(spec, 2),
            plan,
            spec,
            Hypers::default_init(None),
            Arc::new(Accounting::default()),
        );
        let v1 = Mat::from_vec(n, 2, g.rng.normal_vec(n * 2));
        let v2 = Mat::from_vec(n, 2, g.rng.normal_vec(n * 2));
        let (a, b) = (g.rng.normal(), g.rng.normal());
        let mut combo = Mat::zeros(n, 2);
        for i in 0..n {
            for j in 0..2 {
                combo[(i, j)] = a * v1[(i, j)] + b * v2[(i, j)];
            }
        }
        let lhs = op.mvm(&combo);
        let r1 = op.mvm(&v1);
        let r2 = op.mvm(&v2);
        let mut rhs = Mat::zeros(n, 2);
        for i in 0..n {
            for j in 0..2 {
                rhs[(i, j)] = a * r1[(i, j)] + b * r2[(i, j)];
            }
        }
        if lhs.max_abs_diff(&rhs) > 1e-5 * (1.0 + rhs.frob_norm()) {
            return Err(format!("nonlinear: {}", lhs.max_abs_diff(&rhs)));
        }
        Ok(())
    });
}

#[test]
fn prop_mvm_output_psd_quadform() {
    // v^T K^ v > 0 for v != 0 (K^ SPD), through the full stack.
    let spec = TileSpec { r: 4, c: 4, t: 1, d: 2 };
    check("mvm-psd", 16, |g| {
        let n = 3 + g.rng.below(30);
        let x: Vec<f64> = (0..n * 2).map(|_| g.rng.normal()).collect();
        let data = Arc::new(PaddedData::new(&x, 2, &spec));
        let plan = Plan::with_rows(data.n_pad, data.n_pad, spec.r);
        let op = PartitionedKernelOp::square(
            data,
            native_pool(spec, 1),
            plan,
            spec,
            Hypers::default_init(None),
            Arc::new(Accounting::default()),
        );
        let v = g.rng.normal_vec(n);
        let kv = op.mvm(&Mat::col_vec(&v));
        let quad: f64 = (0..n).map(|i| v[i] * kv[(i, 0)]).sum();
        if quad <= 0.0 {
            return Err(format!("v^T K v = {quad}"));
        }
        Ok(())
    });
}

#[test]
fn prop_config_overrides_consistent() {
    check("config-set", 40, |g| {
        let mut cfg = exactgp::config::Config::default();
        let probes = 1 + g.rng.below(64);
        cfg.set("solver.probes", &probes.to_string()).map_err(|e| e.to_string())?;
        if cfg.probes != probes {
            return Err("probes not applied".into());
        }
        if cfg.set("nope.nope", "1").is_ok() {
            return Err("unknown key accepted".into());
        }
        Ok(())
    });
}

#[test]
fn prop_dataset_split_sizes() {
    check("split-sizes", 20, |g| {
        let n = 90 + g.rng.below(4000);
        let raw = exactgp::data::RawData {
            name: "p".into(),
            d: 2,
            x: g.rng.normal_vec(n * 2),
            y: g.rng.normal_vec(n),
        };
        let ds = raw.prepare(32, &mut g.rng);
        let total = ds.n_train() + ds.val_y.len() + ds.n_test();
        if total != n {
            return Err(format!("{total} != {n}"));
        }
        if ds.n_train() != n * 4 / 9 || ds.val_y.len() != n * 2 / 9 {
            return Err("wrong fractions".into());
        }
        Ok(())
    });
}
