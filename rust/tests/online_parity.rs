//! Online-learning parity: the headline invariant of the append path.
//! A model grown in place by `add_data`/`fold_observations` must be
//! **bitwise identical** to a from-scratch model built over the
//! concatenated data under the same hyperparameter trajectory — on both
//! transports, with or without a serve loop in the middle — and the
//! compacted checkpoint of an appended model must match a scratch save
//! byte for byte. The warm-started solve is the one deliberate
//! exception: tolerance-identical, not bitwise, and it must pay fewer
//! mBCG iterations than the cold solve it replaces.

use std::time::Duration;

use exactgp::config::{Backend, Config, TransportKind};
use exactgp::coordinator::{
    self,
    serve::{self, OnlineOptions, ServeOptions},
};
use exactgp::data::synthetic::Scale;
use exactgp::data::Dataset;
use exactgp::faults::FaultPlan;
use exactgp::gp::exact::{ExactGp, Recipe};
use exactgp::runtime::checkpoint;
use exactgp::util::rng::Rng;

/// Training points in the base model before any append.
const N_BASE: usize = 160;
/// The appended chunk sizes, exercised as one cumulative chain: a single
/// point, an unaligned handful, and a chunk far larger than the base's
/// tile rows.
const CHUNKS: [usize; 3] = [1, 17, 1024];

fn base_cfg(transport: TransportKind) -> Config {
    let mut cfg = Config::default();
    cfg.backend = Backend::Native;
    // Large enough that the full training split covers the base plus
    // every appended chunk; the base model sees only a truncated prefix.
    cfg.scale = Scale { train_cap: 1280 };
    cfg.workers = 2;
    cfg.transport = transport;
    cfg.precond_rank = 16;
    cfg.variance_rank = 24;
    cfg
}

/// The same dataset with the training split truncated to its first `n`
/// points — the "model that hasn't seen the rest yet". Using a prefix of
/// one split (rather than two differently-scaled loads) guarantees the
/// appended rows are exactly the rows the from-scratch twin trains on.
fn truncated(ds: &Dataset, n: usize) -> Dataset {
    let mut out = ds.clone();
    out.train_x.truncate(n * ds.d);
    out.train_y.truncate(n);
    out
}

fn cheap_recipe() -> Recipe {
    Recipe { pretrain: false, adam_steps: 1 }
}

/// Train the base prefix, then fold the chunks in one by one, checking
/// each stage bitwise against a from-scratch model over the concatenated
/// prefix (same hypers, same `(seed, n)` RNG derivation that
/// `fold_observations` uses). Returns every stage's prediction bits so
/// the caller can compare transports against each other.
fn run_append_stages(transport: TransportKind) -> Vec<Vec<u64>> {
    let cfg = base_cfg(transport);
    let ds_full = coordinator::load_dataset(&cfg, "bike", 0).unwrap();
    let total: usize = N_BASE + CHUNKS.iter().sum::<usize>();
    assert!(
        ds_full.n_train() >= total,
        "dataset too small: {} < {total}",
        ds_full.n_train()
    );
    let d = ds_full.d;
    let probes = &ds_full.test_x[..32 * d];

    let ds_base = truncated(&ds_full, N_BASE);
    let (pool, spec) = coordinator::make_pool(&cfg, d).unwrap();
    let mut rng = Rng::new(7, 0);
    let mut gp = ExactGp::new(&cfg, cfg.kernel, &ds_base, pool, spec);
    gp.train(cheap_recipe(), &mut rng).unwrap();
    gp.precompute(&mut rng).unwrap();
    let hypers = gp.hypers.clone();

    let mut stages = Vec::new();
    let mut n = N_BASE;
    for k in CHUNKS {
        let new_x = &ds_full.train_x[n * d..(n + k) * d];
        let new_y = &ds_full.train_y[n..n + k];
        gp.fold_observations(new_x, new_y).unwrap();
        n += k;
        assert_eq!(gp.n(), n);

        // The from-scratch twin: a fresh model over the concatenated
        // prefix, handed the same hypers (the "same hyper trajectory"
        // premise) and precomputed with the same deterministic RNG
        // derivation the fold used.
        let ds_n = truncated(&ds_full, n);
        let (pool2, spec2) = coordinator::make_pool(&cfg, d).unwrap();
        let mut scratch = ExactGp::new(&cfg, cfg.kernel, &ds_n, pool2, spec2);
        scratch.hypers = hypers.clone();
        let mut rng2 = Rng::new(cfg.seed, n as u64);
        scratch.precompute(&mut rng2).unwrap();

        let got = gp.predict(probes).unwrap();
        let want = scratch.predict(probes).unwrap();
        for i in 0..want.mean.len() {
            assert_eq!(
                got.mean[i].to_bits(),
                want.mean[i].to_bits(),
                "mean[{i}] diverged from scratch after appending {k} (n={n}, \
                 transport {transport:?})"
            );
            assert_eq!(
                got.var[i].to_bits(),
                want.var[i].to_bits(),
                "var[{i}] diverged from scratch after appending {k} (n={n}, \
                 transport {transport:?})"
            );
        }
        stages.push(
            got.mean
                .iter()
                .chain(got.var.iter())
                .map(|v| v.to_bits())
                .collect(),
        );
    }

    // The append counters tell the same story on every transport.
    let snap = gp.accounting().snapshot();
    assert_eq!(snap.append_calls, CHUNKS.len() as u64);
    assert_eq!(snap.append_rows, CHUNKS.iter().sum::<usize>() as u64);
    assert_eq!(snap.append_folds, CHUNKS.len() as u64);
    stages
}

/// The headline invariant, chunk sizes {1, 17, 1024}: append == scratch
/// bitwise at every stage, on the local transport and over worker
/// processes — and the two transports agree with *each other* bit for
/// bit, stage by stage.
#[test]
fn appended_model_matches_from_scratch_bitwise_on_both_transports() {
    let local = run_append_stages(TransportKind::Local);
    let subprocess = run_append_stages(TransportKind::Subprocess);
    assert_eq!(
        local, subprocess,
        "online-parity stages diverged between transports"
    );
}

/// A model trained by the cheap deterministic recipe (shared by the
/// serve-loop and warm-start tests, which each need two identical
/// copies).
fn trained_small(cfg: &Config, rng_seed: u64) -> (ExactGp, Dataset) {
    let ds = coordinator::load_dataset(cfg, "bike", 0).unwrap();
    let (pool, spec) = coordinator::make_pool(cfg, ds.d).unwrap();
    let mut rng = Rng::new(rng_seed, 0);
    let mut gp = ExactGp::new(cfg, cfg.kernel, &ds, pool, spec);
    gp.train(cheap_recipe(), &mut rng).unwrap();
    gp.precompute(&mut rng).unwrap();
    (gp, ds)
}

/// Observations routed through a live `run_online` serve loop (buffered,
/// folded between dispatches, acked only once folded) land bitwise where
/// direct `fold_observations` calls land — the loop adds plumbing, not
/// arithmetic. Also pins the loop's observation accounting.
#[test]
fn serve_loop_observe_matches_direct_fold_bitwise() {
    let mut cfg = base_cfg(TransportKind::Local);
    cfg.scale = Scale { train_cap: 192 };

    // Two bitwise-identical models: same config, same training RNG.
    let (mut gp_direct, ds) = trained_small(&cfg, 21);
    let (mut gp_serve, _) = trained_small(&cfg, 21);
    let d = ds.d;

    // Two chunks from the test split: one exactly at the fold threshold,
    // one well past it (folded in a single oversized batch).
    let (k1, k2) = (16usize, 48usize);
    let c1x = ds.test_x[..k1 * d].to_vec();
    let c1y = ds.test_y[..k1].to_vec();
    let c2x = ds.test_x[k1 * d..(k1 + k2) * d].to_vec();
    let c2y = ds.test_y[k1..k1 + k2].to_vec();
    let m = 16usize;
    let probe_base = (k1 + k2) * d;
    let probes = &ds.test_x[probe_base..probe_base + m * d];

    gp_direct.fold_observations(&c1x, &c1y).unwrap();
    gp_direct.fold_observations(&c2x, &c2y).unwrap();
    let want = gp_direct.predict(probes).unwrap();

    let (handle, rx) = serve::channel(gp_serve.dim());
    let opts = ServeOptions::new(16, Duration::from_millis(5));
    let online = OnlineOptions {
        buffer_points: k1,
        fold_max_delay: Duration::from_millis(10),
    };
    let (stats, replies) = std::thread::scope(|s| {
        let loop_thread =
            s.spawn(|| serve::run_online(&mut gp_serve, rx, &opts, &online));
        // observe_blocking returns only once the chunk is *folded*, so
        // the serve model walks the exact fold sequence the direct one
        // did: fold(c1), fold(c2).
        handle.observe_blocking(c1x.clone(), c1y.clone()).unwrap();
        handle.observe_blocking(c2x.clone(), c2y.clone()).unwrap();
        let replies: Vec<_> = (0..m)
            .map(|qi| {
                handle
                    .query(probes[qi * d..(qi + 1) * d].to_vec())
                    .unwrap()
            })
            .collect();
        drop(handle);
        (loop_thread.join().unwrap().unwrap(), replies)
    });

    assert_eq!(stats.observations, (k1 + k2) as u64);
    assert_eq!(stats.folds, 2, "expected one fold per chunk: {stats:?}");
    for (qi, p) in replies.iter().enumerate() {
        assert_eq!(p.mean.len(), 1);
        assert_eq!(
            p.mean[0].to_bits(),
            want.mean[qi].to_bits(),
            "serve-loop mean[{qi}] diverged from direct fold"
        );
        assert_eq!(
            p.var[0].to_bits(),
            want.var[qi].to_bits(),
            "serve-loop var[{qi}] diverged from direct fold"
        );
    }
    // The two models are still the same model afterwards.
    let after = gp_serve.predict(probes).unwrap();
    for i in 0..m {
        assert_eq!(after.mean[i].to_bits(), want.mean[i].to_bits());
        assert_eq!(after.var[i].to_bits(), want.var[i].to_bits());
    }
}

/// The warm-started mean solve: seeded from the pre-append `a`, it must
/// converge in strictly fewer mBCG iterations than the cold solve on the
/// same appended model, and land within solver tolerance of the cold
/// answer (it is documented as tolerance-identical, NOT bitwise).
#[test]
fn warm_start_cuts_mean_solve_iterations_within_tolerance() {
    let mut cfg = base_cfg(TransportKind::Local);
    cfg.scale = Scale { train_cap: 512 };
    // Tighten the cache tolerance so the cold solve does real work —
    // at the loose default both paths converge in a handful of
    // iterations and the comparison is noise.
    cfg.predict_tol = 1e-4;

    let (mut gp_cold, ds) = trained_small(&cfg, 33);
    let (mut gp_warm, _) = trained_small(&cfg, 33);
    let d = ds.d;
    let k = 64usize;
    let new_x = &ds.test_x[..k * d];
    let new_y = &ds.test_y[..k];
    let probes = &ds.test_x[k * d..(k + 32) * d];

    gp_cold.fold_observations(new_x, new_y).unwrap();
    let iters_cold = gp_cold.last_mean_solve_iters.unwrap();

    gp_warm.add_data(new_x, new_y).unwrap();
    let mut rng = Rng::new(cfg.seed, gp_warm.n() as u64);
    gp_warm.precompute_warm(&mut rng).unwrap();
    let iters_warm = gp_warm.last_mean_solve_iters.unwrap();

    assert!(iters_cold >= 3, "cold solve trivial ({iters_cold} iters) — the \
             comparison below would be meaningless");
    assert!(
        iters_warm < iters_cold,
        "warm start did not cut iterations: warm {iters_warm} vs cold \
         {iters_cold}"
    );

    // Tolerance-grade agreement: both caches met predict_tol, so their
    // predictions agree to a small multiple of it (whitened units).
    let pc = gp_cold.predict(probes).unwrap();
    let pw = gp_warm.predict(probes).unwrap();
    let mut max_diff = 0.0f64;
    for i in 0..pc.mean.len() {
        max_diff = max_diff.max((pc.mean[i] - pw.mean[i]).abs());
    }
    assert!(
        max_diff <= 1e-3,
        "warm-started predictions drifted {max_diff:.3e} from cold \
         (predict_tol {:.1e})",
        cfg.predict_tol
    );
}

/// Compaction folds the append chain into the base so thoroughly that
/// the result is indistinguishable from never having appended at all:
/// every binary sidecar of the compacted directory equals — byte for
/// byte — a scratch `save` of the same post-append model.
#[test]
fn compacted_append_chain_matches_scratch_save_byte_for_byte() {
    let mut cfg = base_cfg(TransportKind::Local);
    cfg.scale = Scale { train_cap: 192 };
    let (mut gp, mut ds) = trained_small(&cfg, 45);
    let pid = std::process::id();
    let dir_a = std::env::temp_dir().join(format!("exactgp_op_compact_{pid}"));
    let dir_b = std::env::temp_dir().join(format!("exactgp_op_scratch_{pid}"));
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
    gp.save(&dir_a, &ds).unwrap();

    let k = 9usize;
    let new_x = ds.test_x[..k * ds.d].to_vec();
    let new_y = ds.test_y[..k].to_vec();
    gp.fold_observations(&new_x, &new_y).unwrap();
    ds.train_x.extend_from_slice(&new_x);
    ds.train_y.extend_from_slice(&new_y);

    let plan = FaultPlan::default();
    let seq = gp.save_append(&dir_a, &ds, k, &plan).unwrap();
    assert_eq!(seq, 1);
    assert!(dir_a.join("append-000001").is_dir());

    assert_eq!(checkpoint::compact(&dir_a, &plan).unwrap(), 1);
    assert!(
        !dir_a.join("append-000001").exists(),
        "compaction must consume the delta record"
    );

    gp.save(&dir_b, &ds).unwrap();
    let bins = |dir: &std::path::Path| -> Vec<String> {
        let mut v: Vec<String> = std::fs::read_dir(dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".bin"))
            .collect();
        v.sort();
        v
    };
    let names = bins(&dir_b);
    assert!(names.len() >= 5, "expected the full sidecar set, got {names:?}");
    assert_eq!(bins(&dir_a), names, "compacted sidecar set differs");
    for name in &names {
        let a = std::fs::read(dir_a.join(name)).unwrap();
        let b = std::fs::read(dir_b.join(name)).unwrap();
        assert_eq!(a, b, "{name} differs between compacted and scratch save");
    }

    // And the compacted checkpoint still loads into the appended model.
    let (gp2, _) = coordinator::load_model(&cfg, &dir_a).unwrap();
    assert_eq!(gp2.n(), gp.n());
    let probes = &ds.test_x[k * ds.d..(k + 16) * ds.d];
    let want = gp.predict(probes).unwrap();
    let got = gp2.predict(probes).unwrap();
    for i in 0..want.mean.len() {
        assert_eq!(got.mean[i].to_bits(), want.mean[i].to_bits());
        assert_eq!(got.var[i].to_bits(), want.var[i].to_bits());
    }

    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}
