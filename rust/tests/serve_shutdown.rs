//! Graceful shutdown of the networked serving tier (`serve --listen`):
//! a real `exactgp` process under client load receives SIGTERM and must
//! drain every in-flight request — every reply that arrives is complete
//! and bitwise-correct, never a torn frame — flush its final stats, and
//! exit 0.

mod server_common;

use std::io::{BufRead, BufReader, Read};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use exactgp::server::{Client, PredictOutcome};

fn wait_with_deadline(child: &mut Child, deadline: Duration) -> std::process::ExitStatus {
    let t0 = Instant::now();
    loop {
        if let Some(status) = child.try_wait().unwrap() {
            return status;
        }
        if t0.elapsed() > deadline {
            let _ = child.kill();
            let _ = child.wait();
            panic!("server did not exit within {deadline:?} of SIGTERM");
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// An error on a drained connection must look like a close, never like a
/// half-delivered frame.
fn assert_not_torn(err: &str) {
    for torn in ["mid-frame", "not valid JSON", "not UTF-8"] {
        assert!(
            !err.contains(torn),
            "client observed a torn reply during shutdown: {err}"
        );
    }
}

#[test]
fn sigterm_under_load_drains_and_exits_zero() {
    let fx = server_common::fixture();
    let m = &fx.models[0];

    let mut child = Command::new(env!("CARGO_BIN_EXE_exactgp"))
        .args([
            "serve",
            "--listen",
            "127.0.0.1:0",
            "--models",
            &format!("{}={}", m.name, m.dir.display()),
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawning serve --listen");

    // The server prints its bound address (ephemeral port) on stdout.
    let mut stdout = BufReader::new(child.stdout.take().unwrap());
    let addr = {
        let mut line = String::new();
        let t0 = Instant::now();
        loop {
            line.clear();
            let n = stdout.read_line(&mut line).unwrap();
            if let Some(rest) = line.trim().strip_prefix("listening on ") {
                break rest.to_string();
            }
            assert!(
                n > 0 && t0.elapsed() < Duration::from_secs(60),
                "server never announced its address (last line: {line:?})"
            );
        }
    };

    // Client load: three threads hammer single-point predicts, verifying
    // every answer bitwise against the direct-predict reference, until
    // the drained server closes their connections.
    let ok_count = Arc::new(AtomicU64::new(0));
    let mut clients = Vec::new();
    for t in 0..3usize {
        let addr = addr.clone();
        let ok_count = ok_count.clone();
        clients.push(std::thread::spawn(move || -> Option<String> {
            let m = &server_common::fixture().models[0];
            let mut cl = match Client::connect(&addr) {
                Ok(cl) => cl,
                Err(e) => return Some(format!("{e:#}")),
            };
            let mut qi = t; // distinct query streams per thread
            loop {
                qi = (qi + 1) % m.points();
                match cl.predict(m.name, m.point(qi)) {
                    Ok(PredictOutcome::Answer(p)) => {
                        assert_eq!(p.mean.len(), 1);
                        assert_eq!(
                            p.mean[0].to_bits(),
                            m.mean[qi].to_bits(),
                            "reply mean differs from direct predict"
                        );
                        assert_eq!(p.var[0].to_bits(), m.var[qi].to_bits());
                        ok_count.fetch_add(1, Ordering::SeqCst);
                    }
                    Ok(PredictOutcome::Shed(_)) => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Ok(PredictOutcome::Failed(msg)) => {
                        panic!("non-retryable predict failure: {msg}")
                    }
                    Err(e) => return Some(format!("{e:#}")),
                }
            }
        }));
    }

    // Let real traffic flow, then SIGTERM mid-load.
    let t0 = Instant::now();
    while ok_count.load(Ordering::SeqCst) < 10 {
        assert!(
            t0.elapsed() < Duration::from_secs(120),
            "server answered only {} requests in 120s",
            ok_count.load(Ordering::SeqCst)
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    let term = Command::new("kill")
        .args(["-TERM", &child.id().to_string()])
        .status()
        .expect("running kill");
    assert!(term.success(), "kill -TERM failed");

    // Every client either keeps getting complete bitwise-correct replies
    // or sees a clean close — never a torn frame.
    for handle in clients {
        if let Some(err) = handle.join().expect("client thread panicked") {
            assert_not_torn(&err);
        }
    }

    let status = wait_with_deadline(&mut child, Duration::from_secs(60));
    assert!(status.success(), "serve --listen exited nonzero: {status:?}");
    assert!(ok_count.load(Ordering::SeqCst) >= 10);

    let mut err_text = String::new();
    child.stderr.take().unwrap().read_to_string(&mut err_text).unwrap();
    assert!(
        err_text.contains("shutdown signal received; draining in-flight requests"),
        "stderr missing drain marker:\n{err_text}"
    );
    assert!(
        err_text.contains("final per-model stats:"),
        "stderr missing the final stats flush:\n{err_text}"
    );
    assert!(
        err_text.contains("drained; exiting cleanly"),
        "stderr missing clean-exit marker:\n{err_text}"
    );
}
