//! Integration: the AOT bridge end to end.
//!
//! Loads the real `artifacts/` manifest, compiles the HLO with the PJRT
//! CPU client inside pool workers, and checks the partitioned kernel
//! operator's numerics against the pure-Rust native backend — the same
//! tile contract computed by two completely independent stacks
//! (jax/XLA vs hand-written Rust).
//!
//! Requires `make artifacts` (any profile). Tests self-skip when the
//! manifest is missing so `cargo test` stays runnable pre-AOT.

use std::path::Path;
use std::sync::Arc;

use exactgp::config::{Backend, Config, Flavor};
use exactgp::exec::{backend_factory, PaddedData, PartitionedKernelOp, TileSpec};
use exactgp::exec::pool::DevicePool;
use exactgp::kernels::{Hypers, KernelKind};
use exactgp::linalg::Mat;
use exactgp::metrics::Accounting;
use exactgp::partition::Plan;
use exactgp::solvers::BatchMvm;
use exactgp::util::rng::Rng;

/// PJRT needs both the compiled artifacts on disk and a build with the
/// real `xla`-backed engine (the default build substitutes a stub).
fn artifacts_available() -> bool {
    cfg!(feature = "xla") && Path::new("artifacts/manifest.json").exists()
}

fn build_op(flavor: Flavor, workers: usize, hypers: Hypers, x: &[f64], d: usize)
    -> anyhow::Result<PartitionedKernelOp>
{
    let spec = TileSpec::PROD;
    let mut cfg = Config::default();
    cfg.backend = Backend::Pjrt;
    cfg.flavor = flavor;
    let factory = backend_factory(&cfg, KernelKind::Matern32, false, spec.d, spec)?;
    let pool = Arc::new(DevicePool::new(workers, factory)?);
    let data = Arc::new(PaddedData::new(x, d, &spec));
    let plan = Plan::with_rows(data.n_pad, data.n_pad, spec.r);
    Ok(PartitionedKernelOp::square(
        data,
        pool,
        plan,
        spec,
        hypers,
        Arc::new(Accounting::default()),
    ))
}

fn native_op(workers: usize, hypers: Hypers, x: &[f64], d: usize) -> PartitionedKernelOp {
    let spec = TileSpec::PROD;
    let mut cfg = Config::default();
    cfg.backend = Backend::Native;
    let factory = backend_factory(&cfg, KernelKind::Matern32, false, spec.d, spec).unwrap();
    let pool = Arc::new(DevicePool::new(workers, factory).unwrap());
    let data = Arc::new(PaddedData::new(x, d, &spec));
    let plan = Plan::with_rows(data.n_pad, data.n_pad, spec.r);
    PartitionedKernelOp::square(data, pool, plan, spec, hypers, Arc::new(Accounting::default()))
}

#[test]
fn pjrt_jnp_mvm_matches_native() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Rng::new(61, 0);
    let (n, d) = (700, 5);
    let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
    let hypers = Hypers {
        log_lengthscales: vec![0.25],
        log_outputscale: 0.1,
        log_noise: (0.2f64).ln(),
    };
    let v = Mat::from_vec(n, 3, rng.normal_vec(n * 3));

    let pjrt = build_op(Flavor::Jnp, 1, hypers.clone(), &x, d).unwrap();
    let native = native_op(1, hypers, &x, d);
    let a = pjrt.mvm(&v);
    let b = native.mvm(&v);
    let scale = b.frob_norm() / (b.rows as f64).sqrt();
    assert!(
        a.max_abs_diff(&b) < 1e-3 * scale.max(1.0),
        "pjrt vs native diff = {}",
        a.max_abs_diff(&b)
    );
}

#[test]
fn pjrt_pallas_matches_jnp_flavor() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Rng::new(62, 0);
    let (n, d) = (600, 4);
    let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
    let hypers = Hypers {
        log_lengthscales: vec![0.0],
        log_outputscale: 0.0,
        log_noise: (0.1f64).ln(),
    };
    let v = Mat::from_vec(n, 2, rng.normal_vec(n * 2));
    let jnp = build_op(Flavor::Jnp, 1, hypers.clone(), &x, d).unwrap();
    let pallas = build_op(Flavor::Pallas, 1, hypers, &x, d).unwrap();
    let a = jnp.mvm(&v);
    let b = pallas.mvm(&v);
    assert!(a.max_abs_diff(&b) < 1e-3, "pallas vs jnp diff = {}", a.max_abs_diff(&b));
}

#[test]
fn pjrt_grads_match_native() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Rng::new(63, 0);
    let (n, d) = (520, 3);
    let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
    let hypers = Hypers {
        log_lengthscales: vec![-0.2],
        log_outputscale: 0.3,
        log_noise: (0.15f64).ln(),
    };
    let v = Mat::from_vec(n, 2, rng.normal_vec(n * 2));
    let pjrt = build_op(Flavor::Jnp, 1, hypers.clone(), &x, d).unwrap();
    let native = native_op(1, hypers, &x, d);
    let (akv, ag) = pjrt.apply_grads(&v);
    let (bkv, bg) = native.apply_grads(&v);
    assert!(akv.max_abs_diff(&bkv) < 2e-3, "kv diff {}", akv.max_abs_diff(&bkv));
    assert_eq!(ag.len(), bg.len());
    for (x, y) in ag.iter().zip(&bg) {
        assert!(x.max_abs_diff(y) < 2e-3, "grad diff {}", x.max_abs_diff(y));
    }
}

#[test]
fn pjrt_multi_worker_consistent() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Rng::new(64, 0);
    let (n, d) = (900, 4);
    let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
    let hypers = Hypers::default_init(None);
    let v = Mat::from_vec(n, 2, rng.normal_vec(n * 2));
    let one = build_op(Flavor::Jnp, 1, hypers.clone(), &x, d).unwrap().mvm(&v);
    let four = build_op(Flavor::Jnp, 4, hypers, &x, d).unwrap().mvm(&v);
    assert!(one.max_abs_diff(&four) < 1e-12, "diff {}", one.max_abs_diff(&four));
}
