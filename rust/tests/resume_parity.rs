//! Crash-safe resumable training (the robustness contract of PR 8):
//!
//! * a run crashed at a scripted step via the `train.crash` fault seam —
//!   under both the `local` and `subprocess` transports — resumes from
//!   its last durable training-state record and converges to a final
//!   checkpoint **bitwise identical** to an uninterrupted run (every
//!   sidecar byte, every hyperparameter bit, every step-log NLL);
//! * the accounting proves the resumed run actually *skipped* the
//!   completed steps (one mBCG solve per Adam step);
//! * a crash inside the checkpoint writer itself (`ckpt.enospc`) aborts
//!   training but leaves the previous record durable, and resume from it
//!   is still bitwise;
//! * the training-state records are cleared once the final model is
//!   durable.

use std::path::{Path, PathBuf};

use exactgp::config::{Backend, Config, TransportKind};
use exactgp::coordinator::{self, Durability, ExactRecipe};
use exactgp::gp::FitReport;
use exactgp::runtime::checkpoint;

fn base_cfg(transport: TransportKind) -> Config {
    let mut cfg = Config::default();
    cfg.backend = Backend::Native;
    cfg.scale = exactgp::data::synthetic::Scale { train_cap: 192 };
    cfg.workers = 2;
    cfg.transport = transport;
    cfg.pretrain_subset = 64;
    cfg.pretrain_lbfgs_steps = 2;
    cfg.pretrain_adam_steps = 2;
    cfg.finetune_adam_steps = 6;
    cfg.probes = 4;
    cfg.precond_rank = 10;
    cfg.variance_rank = 16;
    cfg
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("exactgp_rp_{tag}_{}", std::process::id()))
}

fn extra(report: &FitReport, key: &str) -> f64 {
    report
        .extra
        .iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| *v)
        .unwrap_or_else(|| panic!("report has no extra {key:?}"))
}

fn run_durable(cfg: &Config, dir: &Path, resume: bool) -> anyhow::Result<FitReport> {
    let ds = coordinator::load_dataset(cfg, "bike", 0).unwrap();
    let dur = Durability { dir: dir.to_path_buf(), every: 1, resume };
    coordinator::run_exact(cfg, &ds, 0, ExactRecipe::PretrainFinetune, Some(&dur))
}

/// Byte-compare every binary sidecar of two checkpoints; the manifests'
/// array checksums then pin the rest.
fn assert_sidecars_identical(a: &Path, b: &Path) {
    let mut names: Vec<String> = std::fs::read_dir(a)
        .unwrap()
        .flatten()
        .map(|e| e.file_name().to_string_lossy().to_string())
        .filter(|n| n.ends_with(".bin"))
        .collect();
    names.sort();
    assert!(!names.is_empty(), "checkpoint {a:?} has no sidecars");
    for n in &names {
        let ba = std::fs::read(a.join(n)).unwrap();
        let bb = std::fs::read(b.join(n))
            .unwrap_or_else(|e| panic!("{b:?} is missing sidecar {n}: {e}"));
        assert_eq!(ba, bb, "sidecar {n} differs between {a:?} and {b:?}");
    }
}

/// The loaded-model view of bitwise parity: hypers, prediction cache, and
/// the step log (timings excluded — wall clock is the one thing a resumed
/// run may legitimately differ in).
fn assert_checkpoints_identical(a: &Path, b: &Path) {
    assert_sidecars_identical(a, b);
    let ca = checkpoint::load(a).unwrap();
    let cb = checkpoint::load(b).unwrap();
    assert_eq!(ca.kernel, cb.kernel);
    assert_eq!(ca.config_fingerprint, cb.config_fingerprint);
    assert_eq!(
        ca.hypers.log_lengthscales.len(),
        cb.hypers.log_lengthscales.len()
    );
    for (x, y) in ca.hypers.log_lengthscales.iter().zip(&cb.hypers.log_lengthscales) {
        assert_eq!(x.to_bits(), y.to_bits(), "lengthscale bits differ");
    }
    assert_eq!(
        ca.hypers.log_outputscale.to_bits(),
        cb.hypers.log_outputscale.to_bits()
    );
    assert_eq!(ca.hypers.log_noise.to_bits(), cb.hypers.log_noise.to_bits());
    assert_eq!(ca.pred_rhs.rows, cb.pred_rhs.rows);
    assert_eq!(ca.pred_rhs.cols, cb.pred_rhs.cols);
    for (x, y) in ca.pred_rhs.data.iter().zip(&cb.pred_rhs.data) {
        assert_eq!(x.to_bits(), y.to_bits(), "pred_rhs bits differ");
    }
    assert_eq!(ca.step_log.len(), cb.step_log.len());
    for (x, y) in ca.step_log.iter().zip(&cb.step_log) {
        assert_eq!(x.step, y.step);
        assert_eq!(x.nll.to_bits(), y.nll.to_bits(), "step {} NLL differs", x.step);
        assert_eq!(x.cg_iters, y.cg_iters);
    }
}

fn crash_resume_case(transport: TransportKind, tname: &str, crash_at: usize) {
    // Subprocess workers are the exactgp binary, not this test binary.
    std::env::set_var("EXACTGP_WORKER_BIN", env!("CARGO_BIN_EXE_exactgp"));

    let dir_a = tmp_dir(&format!("straight_{tname}_{crash_at}"));
    let dir_b = tmp_dir(&format!("crashed_{tname}_{crash_at}"));
    for d in [&dir_a, &dir_b] {
        let _ = std::fs::remove_dir_all(d);
        let _ = std::fs::remove_dir_all(checkpoint::train_state_root(d));
    }

    // Uninterrupted reference run.
    let cfg = base_cfg(transport);
    let report_a = run_durable(&cfg, &dir_a, false).unwrap();
    assert!(checkpoint::exists(&dir_a));
    assert!(
        !checkpoint::train_state_exists(&dir_a),
        "training state must be cleared once the final model is durable"
    );

    // Scripted crash after `crash_at` completed (and checkpointed) steps.
    let mut crashed = base_cfg(transport);
    crashed.faults = format!("train.crash:{crash_at}");
    let err = run_durable(&crashed, &dir_b, false).unwrap_err();
    assert!(format!("{err}").contains("train.crash"), "{err}");
    assert!(
        !checkpoint::exists(&dir_b),
        "a crashed run must not publish a final model checkpoint"
    );
    assert!(checkpoint::train_state_exists(&dir_b));
    let st = checkpoint::load_train_state(&dir_b).unwrap();
    assert_eq!(st.step, crash_at, "last durable record is the crash step");

    // Resume; the final checkpoint must be bitwise what run A produced.
    let report_b = run_durable(&cfg, &dir_b, true).unwrap();
    assert!(checkpoint::exists(&dir_b));
    assert!(!checkpoint::train_state_exists(&dir_b));
    assert_checkpoints_identical(&dir_a, &dir_b);

    // Skipped-steps accounting: one mBCG solve per Adam step, so the
    // resumed run performed exactly `crash_at` fewer of them.
    assert_eq!(extra(&report_b, "resumed_from_step") as usize, crash_at);
    let solves_a = extra(&report_a, "train_mbcg_solves") as i64;
    let solves_b = extra(&report_b, "train_mbcg_solves") as i64;
    assert_eq!(
        solves_a - solves_b,
        crash_at as i64,
        "resumed run must skip exactly the completed steps \
         (straight {solves_a} vs resumed {solves_b})"
    );

    for d in [&dir_a, &dir_b] {
        let _ = std::fs::remove_dir_all(d);
        let _ = std::fs::remove_dir_all(checkpoint::train_state_root(d));
    }
}

#[test]
fn crash_and_resume_is_bitwise_local_early() {
    crash_resume_case(TransportKind::Local, "local", 1);
}

#[test]
fn crash_and_resume_is_bitwise_local_late() {
    crash_resume_case(TransportKind::Local, "local", 4);
}

#[test]
fn crash_and_resume_is_bitwise_subprocess() {
    crash_resume_case(TransportKind::Subprocess, "subproc", 4);
}

/// A crash *inside the checkpoint writer* (simulated full disk while
/// writing the step-3 record) aborts training, but the step-2 record is
/// already durable — resume from it is still bitwise.
#[test]
fn enospc_during_record_write_resumes_from_previous_record() {
    let dir_a = tmp_dir("straight_enospc");
    let dir_b = tmp_dir("crashed_enospc");
    for d in [&dir_a, &dir_b] {
        let _ = std::fs::remove_dir_all(d);
        let _ = std::fs::remove_dir_all(checkpoint::train_state_root(d));
    }

    let cfg = base_cfg(TransportKind::Local);
    let report_a = run_durable(&cfg, &dir_a, false).unwrap();

    // Each record writes 3 sidecars (params, adam_m, adam_v); hit 7 is
    // the first sidecar of the step-3 record.
    let mut crashed = base_cfg(TransportKind::Local);
    crashed.faults = "ckpt.enospc:7".into();
    let err = run_durable(&crashed, &dir_b, false).unwrap_err();
    assert!(format!("{err:#}").contains("ckpt.enospc"), "{err:#}");
    let st = checkpoint::load_train_state(&dir_b).unwrap();
    assert_eq!(st.step, 2, "the step-2 record must have survived the ENOSPC crash");

    let report_b = run_durable(&cfg, &dir_b, true).unwrap();
    assert_checkpoints_identical(&dir_a, &dir_b);
    let solves_a = extra(&report_a, "train_mbcg_solves") as i64;
    let solves_b = extra(&report_b, "train_mbcg_solves") as i64;
    assert_eq!(solves_a - solves_b, 2);

    for d in [&dir_a, &dir_b] {
        let _ = std::fs::remove_dir_all(d);
        let _ = std::fs::remove_dir_all(checkpoint::train_state_root(d));
    }
}

/// `--resume` against a directory with no records fails with guidance,
/// and a dataset mismatch is refused before any training runs.
#[test]
fn resume_guardrails() {
    let dir = tmp_dir("guardrails");
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(checkpoint::train_state_root(&dir));

    let cfg = base_cfg(TransportKind::Local);
    let err = run_durable(&cfg, &dir, true).unwrap_err();
    assert!(format!("{err}").contains("nothing to resume"), "{err}");

    // Crash a run on "bike", then try to resume it as "elevators".
    let mut crashed = base_cfg(TransportKind::Local);
    crashed.faults = "train.crash:1".into();
    let _ = run_durable(&crashed, &dir, false).unwrap_err();
    let ds = coordinator::load_dataset(&cfg, "elevators", 0).unwrap();
    let dur = Durability { dir: dir.clone(), every: 1, resume: true };
    let err = coordinator::run_exact(&cfg, &ds, 0, ExactRecipe::PretrainFinetune, Some(&dur))
        .unwrap_err();
    assert!(format!("{err}").contains("belongs to dataset"), "{err}");

    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(checkpoint::train_state_root(&dir));
}
