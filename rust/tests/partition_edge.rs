//! Partition-plan edge cases: n not divisible by the worker count, a
//! single worker, and more workers than rows. In every configuration the
//! partitioned MVM must agree bit-for-bit-close (<= 1e-10) with a
//! reference single-worker, single-partition run — per-row accumulation
//! order is independent of how rows are grouped into jobs — and with the
//! f64 dense oracle to f32 tile precision.

use std::sync::Arc;

use exactgp::exec::{
    native::NativeBackend, pool::DevicePool, BackendFactory, PaddedData, PartitionedKernelOp,
    TileBackend, TileSpec,
};
use exactgp::kernels::{Hypers, KernelEval, KernelKind};
use exactgp::linalg::Mat;
use exactgp::metrics::Accounting;
use exactgp::partition::Plan;
use exactgp::solvers::{BatchMvm, DenseOp};
use exactgp::util::rng::Rng;

const SPEC: TileSpec = TileSpec { r: 4, c: 8, t: 2, d: 3 };

fn hypers() -> Hypers {
    Hypers {
        log_lengthscales: vec![0.15],
        log_outputscale: 0.1,
        log_noise: (0.3f64).ln(),
    }
}

fn build_op(x: &[f64], workers: usize, rows_per_partition: usize) -> PartitionedKernelOp {
    let factory: BackendFactory = Arc::new(move |_| {
        Ok(Box::new(NativeBackend::new(KernelKind::Matern32, false, SPEC))
            as Box<dyn TileBackend>)
    });
    let pool = Arc::new(DevicePool::new(workers, factory).unwrap());
    let data = Arc::new(PaddedData::new(x, SPEC.d, &SPEC));
    let plan = Plan::with_rows(data.n_pad, data.n_pad, rows_per_partition);
    PartitionedKernelOp::square(
        data,
        pool,
        plan,
        SPEC,
        hypers(),
        Arc::new(Accounting::default()),
    )
}

/// Reference: one worker, one partition — plus the dense f64 oracle.
fn check_config(n: usize, workers: usize, rows_per_partition: usize) {
    let mut rng = Rng::new(97, n as u64);
    let x: Vec<f64> = (0..n * SPEC.d).map(|_| rng.normal()).collect();
    let v = Mat::from_vec(n, SPEC.t, rng.normal_vec(n * SPEC.t));

    let reference = build_op(&x, 1, usize::MAX / 2).mvm(&v);
    let got = build_op(&x, workers, rows_per_partition).mvm(&v);
    assert!(
        got.max_abs_diff(&reference) < 1e-10,
        "n={n} workers={workers} rpp={rows_per_partition}: diff vs reference = {}",
        got.max_abs_diff(&reference)
    );

    // Dense oracle (f64 kernel evaluation wrapped in DenseOp): the tile
    // path computes in f32, so the agreement bound is f32-scale.
    let eval = KernelEval::new(KernelKind::Matern32, &hypers());
    let dense = DenseOp { a: eval.gram_with_noise(&x, SPEC.d, hypers().noise()) };
    let want = dense.mvm(&v);
    let scale = want.frob_norm() / (want.rows as f64).sqrt();
    assert!(
        got.max_abs_diff(&want) < 1e-4 * scale.max(1.0),
        "n={n} workers={workers}: diff vs dense oracle = {}",
        got.max_abs_diff(&want)
    );
}

#[test]
fn n_not_divisible_by_worker_count() {
    // 45 rows over 4 workers (45 % 4 != 0), small partitions.
    check_config(45, 4, SPEC.r);
    // ... and a partition size that does not divide n_pad either.
    check_config(45, 3, SPEC.r * 3);
}

#[test]
fn single_worker() {
    check_config(33, 1, SPEC.r);
    check_config(33, 1, 1024);
}

#[test]
fn more_workers_than_rows() {
    // 5 true rows (padded to one column tile), 8 workers: most workers
    // idle, results unchanged.
    check_config(5, 8, SPEC.r);
    check_config(3, 6, 1024);
}
