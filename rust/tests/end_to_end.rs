//! End-to-end integration: the full coordinator pipeline over the PJRT
//! backend, checked for correctness (vs the Cholesky oracle), calibration,
//! and the paper's qualitative claims at test scale.
//!
//! Self-skips when artifacts are missing.

use exactgp::config::{Backend, Config};
use exactgp::coordinator::{self, Model};
use exactgp::data::synthetic::Scale;

/// The PJRT pipeline needs both the artifacts and a build with the real
/// `xla`-backed engine (the default build substitutes a stub).
fn artifacts_available() -> bool {
    cfg!(feature = "xla") && std::path::Path::new("artifacts/manifest.json").exists()
}

fn smoke_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.scale = Scale { train_cap: 768 };
    cfg.backend = Backend::Pjrt;
    cfg
}

#[test]
fn exact_gp_beats_mean_predictor_on_suite_sample() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = smoke_cfg();
    for name in ["poletele", "kin40k", "3droad"] {
        let ds = coordinator::load_dataset(&cfg, name, 0).unwrap();
        let r = coordinator::run_model(&cfg, Model::ExactBbmm, &ds, 0).unwrap();
        assert!(r.rmse < 0.85, "{name}: rmse={} (mean predictor = 1.0)", r.rmse);
        assert!(r.nll.is_finite());
    }
}

#[test]
fn exact_gp_matches_cholesky_gp_quality() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // The BBMM exact GP and the O(n^3) Cholesky GP are the *same model*;
    // their test RMSE must agree closely when trained with the same
    // recipe at small n.
    let mut cfg = smoke_cfg();
    cfg.scale = Scale { train_cap: 512 };
    cfg.predict_tol = 1e-6;
    cfg.variance_rank = 256;
    let ds = coordinator::load_dataset(&cfg, "bike", 0).unwrap();
    let exact = coordinator::run_model(&cfg, Model::ExactBbmm, &ds, 0).unwrap();
    let chol = coordinator::run_model(&cfg, Model::Cholesky, &ds, 0).unwrap();
    assert!(
        (exact.rmse - chol.rmse).abs() < 0.1,
        "bbmm={} chol={}",
        exact.rmse,
        chol.rmse
    );
}

#[test]
fn exact_gp_not_worse_than_approximations() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // The paper's headline (Table 1 shape): exact <= approx error, with
    // a small tolerance for trial noise at smoke scale.
    let cfg = smoke_cfg();
    let ds = coordinator::load_dataset(&cfg, "kin40k", 0).unwrap();
    let exact = coordinator::run_model(&cfg, Model::ExactBbmm, &ds, 0).unwrap();
    let sgpr = coordinator::run_model(&cfg, Model::Sgpr, &ds, 0).unwrap();
    let svgp = coordinator::run_model(&cfg, Model::Svgp, &ds, 0).unwrap();
    assert!(
        exact.rmse <= sgpr.rmse * 1.10,
        "exact {} vs sgpr {}",
        exact.rmse,
        sgpr.rmse
    );
    assert!(
        exact.rmse <= svgp.rmse * 1.10,
        "exact {} vs svgp {}",
        exact.rmse,
        svgp.rmse
    );
}

#[test]
fn more_data_does_not_hurt() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // Figure 4 shape: exact-GP error decreases (or at worst stagnates)
    // as training data grows.
    let mut cfg = smoke_cfg();
    cfg.scale = Scale { train_cap: 1024 };
    let ds = coordinator::load_dataset(&cfg, "3droad", 0).unwrap();
    let mut rng = exactgp::util::rng::Rng::new(3, 0);
    let small = ds.subsample_train(256, &mut rng);
    let r_small = coordinator::run_model(&cfg, Model::ExactBbmm, &small, 0).unwrap();
    let r_full = coordinator::run_model(&cfg, Model::ExactBbmm, &ds, 0).unwrap();
    assert!(
        r_full.rmse <= r_small.rmse * 1.05,
        "full {} vs small {}",
        r_full.rmse,
        r_small.rmse
    );
}

#[test]
fn ard_pipeline_runs() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut cfg = smoke_cfg();
    cfg.ard = true;
    cfg.scale = Scale { train_cap: 512 };
    let ds = coordinator::load_dataset(&cfg, "protein", 0).unwrap();
    let r = coordinator::run_model(&cfg, Model::ExactBbmm, &ds, 0).unwrap();
    assert!(r.rmse < 1.0, "ard rmse={}", r.rmse);
}

#[test]
fn results_json_roundtrips() {
    let mut cfg = smoke_cfg();
    cfg.scale = Scale { train_cap: 256 };
    cfg.results_dir = std::env::temp_dir()
        .join("exactgp_e2e_results")
        .to_string_lossy()
        .into_owned();
    let ds = coordinator::load_dataset(&cfg, "elevators", 0).unwrap();
    let r = coordinator::run_model(&cfg, Model::Cholesky, &ds, 0).unwrap();
    let path = coordinator::write_results(&cfg, "test_exp", &[r]).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let j = exactgp::util::json::Json::parse(&text).unwrap();
    assert_eq!(j.req_str("experiment").unwrap(), "test_exp");
    let rows = j.req("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 1);
    assert!(rows[0].req("rmse").unwrap().as_f64().unwrap() > 0.0);
    std::fs::remove_dir_all(&cfg.results_dir).ok();
}
