//! Figure 2: training speedup from additional workers ("GPUs").
//!
//! The paper's figure shows near-linear MVM speedup up to 4 GPUs on
//! KEGGU/3DRoad/Song/Buzz. Our testbed is ONE CPU core (DESIGN.md SS5), so
//! the *measured* wall-clock column mostly shows scheduling overhead; the
//! figure's underlying quantity — work distribution across devices — is
//! reported via the work-balance model: ideal speedup = total partitions /
//! ceil(partitions / workers) (perfect if p % w == 0).

use std::sync::Arc;

use exactgp::bench_harness::{time_fn, BenchEnv};
use exactgp::coordinator::{self};
use exactgp::exec::{backend_factory, pool::DevicePool, PaddedData, PartitionedKernelOp, TileSpec};
use exactgp::kernels::Hypers;
use exactgp::linalg::Mat;
use exactgp::metrics::Accounting;
use exactgp::partition::Plan;
use exactgp::util::rng::Rng;

fn main() {
    let env = BenchEnv::from_env(&["keggu", "3droad"]);
    let spec = TileSpec::PROD;
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();

    for name in &env.datasets {
        let Ok(ds) = coordinator::load_dataset(&env.cfg, name, 0) else {
            continue;
        };
        let data = Arc::new(PaddedData::new(&ds.train_x, ds.d, &spec));
        // Force multiple partitions so distribution is visible.
        let plan = Plan::with_rows(data.n_pad, data.n_pad, spec.r);
        let p = plan.p();
        let mut rng = Rng::new(7, 0);
        let v = Mat::from_vec(ds.n_train(), spec.t, rng.normal_vec(ds.n_train() * spec.t));

        let mut base = f64::NAN;
        for workers in [1usize, 2, 4, 8] {
            let mut cfg = env.cfg.clone();
            cfg.workers = workers;
            let Ok(factory) = backend_factory(&cfg, cfg.kernel, cfg.ard, spec.d, spec) else {
                eprintln!("no backend for {name}; skipping");
                continue;
            };
            let Ok(pool) = DevicePool::new(workers, factory) else { continue };
            let op = PartitionedKernelOp::square(
                data.clone(),
                Arc::new(pool),
                plan.clone(),
                spec,
                Hypers::default_init(None),
                Arc::new(Accounting::default()),
            );
            let stats = time_fn(1, 3, || {
                let _ = op.apply_raw(&v);
            });
            if workers == 1 {
                base = stats.mean;
            }
            let measured = base / stats.mean;
            let ideal = p as f64 / (p as f64 / workers as f64).ceil();
            rows.push(vec![
                format!("{name} (n={}, p={p})", ds.n_train()),
                workers.to_string(),
                stats.fmt_seconds(),
                format!("{measured:.2}x"),
                format!("{ideal:.2}x"),
            ]);
            json_rows.push(exactgp::util::json::obj(vec![
                ("dataset", exactgp::util::json::s(name)),
                ("workers", exactgp::util::json::num(workers as f64)),
                ("mvm_seconds", exactgp::util::json::num(stats.mean)),
                ("measured_speedup", exactgp::util::json::num(measured)),
                ("ideal_speedup", exactgp::util::json::num(ideal)),
            ]));
        }
    }

    coordinator::print_table(
        "Figure 2 — MVM speedup vs workers (measured wall-clock is 1-core bound; \
         'ideal' is the paper's quantity: work balance across devices)",
        &["dataset", "workers", "MVM time", "measured", "ideal (work-balance)"],
        &rows,
    );
    std::fs::create_dir_all(&env.cfg.results_dir).ok();
    let doc = exactgp::util::json::obj(vec![
        ("experiment", exactgp::util::json::s("fig2_speedup")),
        ("rows", exactgp::util::json::Json::Arr(json_rows)),
    ]);
    let path = std::path::Path::new(&env.cfg.results_dir).join("fig2_speedup.json");
    std::fs::write(&path, doc.to_string_pretty()).ok();
    eprintln!("wrote {path:?}");
}
