//! Figure 3: approximate-GP error as a function of the number of inducing
//! points m (Bike and Protein in the paper).
//!
//! Paper shape: SGPR/SVGP RMSE saturates with m well above the exact GP's
//! RMSE — more inducing points do not close the gap, while their cost
//! grows as O(nm^2 + m^3).

use exactgp::bench_harness::BenchEnv;
use exactgp::coordinator::{self, Model};
use exactgp::util::json::{num, obj, s, Json};

fn main() {
    let env = BenchEnv::from_env(&["bike", "protein"]);
    let manifest =
        exactgp::runtime::Manifest::load(std::path::Path::new(&env.cfg.artifacts_dir));
    let (sgpr_menu, svgp_menu) = match &manifest {
        Ok(m) => (
            m.dim_menu("sgpr", "matern32", "shared", "m"),
            m.dim_menu("svgp", "matern32", "shared", "m"),
        ),
        Err(e) => {
            eprintln!("artifacts unavailable ({e}); cannot run inducing-point sweep");
            return;
        }
    };

    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for name in &env.datasets {
        let Ok(ds) = coordinator::load_dataset(&env.cfg, name, 0) else { continue };

        // Exact GP reference line.
        let exact_rmse = match coordinator::run_model(&env.cfg, Model::ExactBbmm, &ds, 0) {
            Ok(r) => r.rmse,
            Err(e) => {
                eprintln!("exact on {name}: {e}");
                f64::NAN
            }
        };
        rows.push(vec![
            format!("{name} (n={})", ds.n_train()),
            "exact-gp".into(),
            "-".into(),
            format!("{exact_rmse:.3}"),
        ]);

        for (model, menu) in [(Model::Sgpr, &sgpr_menu), (Model::Svgp, &svgp_menu)] {
            for &m in menu.iter() {
                if m > ds.n_train() {
                    continue;
                }
                let mut cfg = env.cfg.clone();
                // Pin m by overriding the config caps.
                cfg.sgpr_m = m;
                cfg.svgp_m = m;
                match coordinator::run_model(&cfg, model, &ds, 0) {
                    Ok(r) => {
                        let m_used = r
                            .extra
                            .iter()
                            .find(|(k, _)| k == "m")
                            .map(|(_, v)| *v as usize)
                            .unwrap_or(m);
                        if m_used != m {
                            continue; // snapped away; avoid duplicate rows
                        }
                        rows.push(vec![
                            format!("{name} (n={})", ds.n_train()),
                            model.name().into(),
                            m.to_string(),
                            format!("{:.3}", r.rmse),
                        ]);
                        json_rows.push(obj(vec![
                            ("dataset", s(name)),
                            ("model", s(model.name())),
                            ("m", num(m as f64)),
                            ("rmse", num(r.rmse)),
                            ("exact_rmse", num(exact_rmse)),
                            ("train_seconds", num(r.train_seconds)),
                        ]));
                    }
                    Err(e) => eprintln!("  {} m={m} on {name}: SKIPPED ({e})", model.name()),
                }
            }
        }
    }

    coordinator::print_table(
        "Figure 3 — RMSE vs #inducing points (paper: saturates above exact-GP error)",
        &["dataset", "model", "m", "RMSE"],
        &rows,
    );
    std::fs::create_dir_all(&env.cfg.results_dir).ok();
    let path = std::path::Path::new(&env.cfg.results_dir).join("fig3_inducing.json");
    std::fs::write(
        &path,
        obj(vec![
            ("experiment", s("fig3_inducing")),
            ("rows", Json::Arr(json_rows)),
        ])
        .to_string_pretty(),
    )
    .ok();
    eprintln!("wrote {path:?}");
}
