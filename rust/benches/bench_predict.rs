//! Batched-prediction throughput: the serving-side hot path underneath
//! `ExactGp::predict` (paper SS3 "Predictions" + Table 2's right-hand
//! columns).
//!
//! Benches `exec::CrossKernelOp` directly — K(X*, X) @ [a | W] over a
//! synthetic training set — so no GP training is needed: prediction cost
//! depends only on the shapes, not the cache contents. Reports
//!
//! * batched vs per-point prediction (the acceptance target: batched wins
//!   by >= 5x on a 10k-train / 1k-test case, in `--quick` too), with a
//!   bitwise cross-check that both paths produce identical rows;
//! * a chunk-size x worker-count sweep (points/s), showing the
//!   latency/parallelism tradeoff: one chunk is one pool dispatch, and
//!   chunks shorter than workers x tile height cannot use every worker.
//!
//! Writes `results/BENCH_predict.json` (uploaded by CI next to
//! `BENCH_mvm.json`). Knobs: `EXACTGP_BENCH_N` (train sizes),
//! `EXACTGP_BENCH_WORKERS`, `--quick` / `EXACTGP_BENCH_QUICK=1`.

use std::sync::Arc;

use exactgp::bench_harness::{time_fn, BenchEnv};
use exactgp::config::Backend;
use exactgp::coordinator::print_table;
use exactgp::exec::{backend_factory, pool::DevicePool, CrossKernelOp, PaddedData, TileSpec};
use exactgp::kernels::Hypers;
use exactgp::linalg::Mat;
use exactgp::metrics::Accounting;
use exactgp::util::json::{arr, num, obj, s, Json};
use exactgp::util::rng::Rng;

fn native_pool(env: &BenchEnv, spec: TileSpec, workers: usize) -> Arc<DevicePool> {
    let mut cfg = env.cfg.clone();
    cfg.backend = Backend::Native;
    cfg.workers = workers;
    let factory =
        backend_factory(&cfg, cfg.kernel, false, spec.d, spec).expect("native backend");
    Arc::new(DevicePool::new(workers, factory).expect("pool"))
}

fn cross_op(
    env: &BenchEnv,
    train: &Arc<PaddedData>,
    spec: TileSpec,
    workers: usize,
    chunk: usize,
) -> CrossKernelOp {
    // Budget large enough to hold a full chunk strip resident: the multi-
    // pass [a | W] RHS replays each test-train block gemm-only.
    CrossKernelOp::new(
        train.clone(),
        native_pool(env, spec, workers),
        spec,
        Hypers::default_init(None),
        Arc::new(Accounting::default()),
    )
    .with_cache_budget(256 << 20)
    .with_chunk_rows(chunk)
}

fn main() {
    let env = BenchEnv::from_env(&[]);
    let quick = env.quick;
    let spec = TileSpec::PROD;
    let d = 8;
    let n_train = env.sizes(&[10_240], &[10_240]).first().copied().unwrap_or(10_240);
    let n_test = if quick { 1024 } else { 2048 };
    // RHS width: 1 mean column + r LOVE variance columns (r = 16 keeps the
    // quick run to two t-passes; the full run uses the default rank 64).
    let rhs_cols = if quick { 17 } else { 65 };
    let workers_max = env.cfg.workers.max(1);

    let mut rng = Rng::new(7, 0);
    let xs: Vec<f64> = (0..n_train * d).map(|_| rng.normal()).collect();
    let xt: Vec<f64> = (0..n_test * d).map(|_| rng.normal()).collect();
    let train = Arc::new(PaddedData::new(&xs, d, &spec));
    let v = Mat::from_vec(n_train, rhs_cols, rng.normal_vec(n_train * rhs_cols));

    // --- batched vs per-point -------------------------------------------
    let mut batched_op = cross_op(&env, &train, spec, workers_max, 0);
    let t0 = std::time::Instant::now();
    let batched = batched_op.apply(&xt, d, &v);
    let batched_s = t0.elapsed().as_secs_f64();

    let sample = if quick { 4 } else { 8 };
    let mut per_point_op = cross_op(&env, &train, spec, workers_max, 0);
    let mut per_point_total = 0.0;
    let mut rows_match = true;
    for i in 0..sample {
        let point = &xt[i * d..(i + 1) * d];
        let t0 = std::time::Instant::now();
        let one = per_point_op.apply(point, d, &v);
        per_point_total += t0.elapsed().as_secs_f64();
        // Each output row depends only on its own test point: the batched
        // row must be bitwise-identical to the single-point result.
        rows_match &= one.row(0) == batched.row(i);
    }
    let per_point_s = per_point_total / sample as f64;
    let speedup = per_point_s * n_test as f64 / batched_s;
    assert!(rows_match, "batched and per-point predictions diverged");

    print_table(
        &format!(
            "Batched vs per-point prediction (n_train={n_train}, n_test={n_test}, \
             rhs={rhs_cols} cols, {workers_max} workers)"
        ),
        &["mode", "total", "per point", "speedup"],
        &[
            vec![
                "per-point".into(),
                format!("{:.1}s (extrapolated)", per_point_s * n_test as f64),
                format!("{:.1}ms", per_point_s * 1e3),
                "1.00x".into(),
            ],
            vec![
                "batched".into(),
                format!("{:.2}s", batched_s),
                format!("{:.2}ms", batched_s * 1e3 / n_test as f64),
                format!("{speedup:.0}x"),
            ],
        ],
    );

    // --- chunk-size x worker-count sweep --------------------------------
    let chunks: Vec<usize> = if quick { vec![512, 2048] } else { vec![256, 512, 2048, 8192] };
    let worker_counts: Vec<usize> = if quick { vec![1, workers_max] } else { vec![1, 2, 4] };
    let reps = if quick { 1 } else { 3 };
    let mut sweep_rows = Vec::new();
    let mut sweep_json = Vec::new();
    for &workers in &worker_counts {
        for &chunk in &chunks {
            let chunk = chunk.min(n_test);
            let mut op = cross_op(&env, &train, spec, workers, chunk);
            let stats = time_fn(0, reps, || {
                let _ = op.apply(&xt, d, &v);
            });
            let pps = n_test as f64 / stats.min;
            sweep_rows.push(vec![
                workers.to_string(),
                chunk.to_string(),
                stats.fmt_seconds(),
                format!("{pps:.0}"),
            ]);
            sweep_json.push(obj(vec![
                ("workers", num(workers as f64)),
                ("chunk", num(chunk as f64)),
                ("seconds", num(stats.min)),
                ("points_per_s", num(pps)),
            ]));
        }
    }
    print_table(
        &format!("Prediction throughput sweep (n_train={n_train}, n_test={n_test})"),
        &["workers", "chunk", "time/batch", "points/s"],
        &sweep_rows,
    );

    let doc = obj(vec![
        ("bench", s("bench_predict")),
        ("mode", s(if quick { "quick" } else { "full" })),
        ("n_train", num(n_train as f64)),
        ("n_test", num(n_test as f64)),
        ("rhs_cols", num(rhs_cols as f64)),
        ("workers", num(workers_max as f64)),
        ("batched_s", num(batched_s)),
        ("per_point_s", num(per_point_s)),
        ("batched_vs_per_point_speedup", num(speedup)),
        ("outputs_bitwise_match", Json::Bool(rows_match)),
        ("sweep", arr(sweep_json)),
    ]);
    if std::fs::create_dir_all(&env.cfg.results_dir).is_ok() {
        let path = std::path::Path::new(&env.cfg.results_dir).join("BENCH_predict.json");
        if let Err(e) = std::fs::write(&path, doc.to_string_pretty()) {
            eprintln!("could not write {}: {e}", path.display());
        } else {
            println!("wrote {}", path.display());
        }
    }

    assert!(
        speedup >= 5.0,
        "batched prediction must beat per-point by >= 5x (got {speedup:.1}x)"
    );
}
