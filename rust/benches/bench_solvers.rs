//! Micro-benchmark: mBCG convergence vs preconditioner rank, and log-det
//! estimator accuracy vs probe count — the paper's SS3 "Preconditioning"
//! claims ("preconditioners of up to size k=100 provide a noticeable
//! improvement").

use exactgp::coordinator::print_table;
use exactgp::kernels::{Hypers, KernelEval, KernelKind};
use exactgp::linalg::Mat;
use exactgp::solvers::mbcg::{logdet_from_tridiags, mbcg};
use exactgp::solvers::pivchol::{pivoted_cholesky, NativeKernelRows};
use exactgp::solvers::precond::PivCholPrecond;
use exactgp::solvers::{DenseOp, IdentityPrecond, Preconditioner};
use exactgp::util::rng::Rng;

fn main() {
    // Single-size bench: first entry of a comma-separated EXACTGP_BENCH_N.
    let env = exactgp::bench_harness::BenchEnv::from_env(&[]);
    let n: usize = env.sizes(&[1024], &[1024]).first().copied().unwrap_or(1024);
    let d = 4;
    let noise: f64 = 1e-2;
    let mut rng = Rng::new(11, 0);
    // Clustered inputs -> ill-conditioned K (the regime preconditioning
    // targets; cf. the Kegg* datasets).
    let mut x = Vec::with_capacity(n * d);
    for _ in 0..n {
        let c = rng.below(8) as f64;
        for j in 0..d {
            x.push(c * ((j + 1) as f64 * 0.7).sin() + 0.03 * rng.normal());
        }
    }
    let hypers = Hypers {
        log_lengthscales: vec![0.0],
        log_outputscale: 0.0,
        log_noise: noise.ln(),
    };
    let eval = KernelEval::new(KernelKind::Matern32, &hypers);
    let khat = eval.gram_with_noise(&x, d, noise);
    let truth_logdet = exactgp::linalg::cholesky(&khat).unwrap().logdet();
    let op = DenseOp { a: khat };
    let b = Mat::from_vec(n, 1, rng.normal_vec(n));

    // --- CG iterations vs preconditioner rank ---------------------------
    let mut rows = Vec::new();
    let base_iters = {
        let t0 = std::time::Instant::now();
        let res = mbcg(&op, &IdentityPrecond { n }, &b, 1e-8, 4000, 1);
        rows.push(vec![
            "none (plain CG)".into(),
            res.stats.iterations.to_string(),
            format!("{:.2}s", t0.elapsed().as_secs_f64()),
            "1.00x".into(),
        ]);
        res.stats.iterations as f64
    };
    for k in [10, 25, 50, 100] {
        let t0 = std::time::Instant::now();
        let pc = {
            let kr = NativeKernelRows { eval: &eval, x: &x, d };
            pivoted_cholesky(&kr, k, 0.0)
        };
        let p = PivCholPrecond::new(pc, noise).unwrap();
        let res = mbcg(&op, &p, &b, 1e-8, 4000, 1);
        rows.push(vec![
            format!("pivchol k={k}"),
            res.stats.iterations.to_string(),
            format!("{:.2}s", t0.elapsed().as_secs_f64()),
            format!("{:.2}x", base_iters / res.stats.iterations.max(1) as f64),
        ]);
    }
    print_table(
        &format!(
            "mBCG iterations vs preconditioner rank (n={n}, clustered inputs, \
             tol=1e-8; paper: k up to 100 helps on large/ill-conditioned data)"
        ),
        &["preconditioner", "CG iters", "total time", "iter speedup"],
        &rows,
    );

    // --- log-det estimator accuracy vs #probes --------------------------
    let mut rows2 = Vec::new();
    for t in [4usize, 8, 16, 32] {
        let pc = {
            let kr = NativeKernelRows { eval: &eval, x: &x, d };
            pivoted_cholesky(&kr, 100, 0.0)
        };
        let p = PivCholPrecond::new(pc, noise).unwrap();
        let mut errs = Vec::new();
        for rep in 0..3 {
            let mut rng2 = Rng::new(100 + rep, 0);
            let mut bb = Mat::zeros(n, t);
            for j in 0..t {
                bb.set_col(j, &p.sample_probe(&mut rng2));
            }
            let res = mbcg(&op, &p, &bb, 1e-8, 4000, 0);
            let est = logdet_from_tridiags(&res.tridiags, n, p.logdet()).unwrap();
            errs.push((est - truth_logdet).abs() / truth_logdet.abs());
        }
        let (m, s) = exactgp::metrics::mean_std(&errs);
        rows2.push(vec![
            t.to_string(),
            format!("{m:.4} +/- {s:.4}"),
        ]);
    }
    print_table(
        &format!("log|K| estimator relative error vs probe count (truth={truth_logdet:.1})"),
        &["probes t", "rel. error"],
        &rows2,
    );
}
