//! Table 5 (appendix): exact GPs trained with plain Adam (no subset
//! pretraining), the "fair comparison against SGPR/SVGP trained with
//! Adam" configuration, plus the Figure 5 observation that large datasets
//! need fewer steps than 100.

use exactgp::bench_harness::BenchEnv;
use exactgp::coordinator::{self, ExactRecipe, Model};

fn main() {
    let mut env = BenchEnv::from_env(&["poletele", "bike", "kin40k"]);
    env.cfg.full_adam_steps =
        exactgp::bench_harness::env_usize("EXACTGP_BENCH_FULL_ADAM").unwrap_or(25);

    let mut rows = Vec::new();
    let mut reports = Vec::new();
    for name in &env.datasets {
        let Ok(ds) = coordinator::load_dataset(&env.cfg, name, 0) else { continue };
        // Exact GP with full Adam.
        match coordinator::run_model_with_recipe(
            &env.cfg,
            Model::ExactBbmm,
            &ds,
            0,
            ExactRecipe::FullAdam,
        ) {
            Ok(mut r) => {
                rows.push(vec![
                    format!("{name} (n={})", ds.n_train()),
                    format!("exact-gp ({} Adam)", env.cfg.full_adam_steps),
                    format!("{:.3}", r.rmse),
                    format!("{:.1}s", r.train_seconds),
                ]);
                r.model = "exact-gp-fulladam".into();
                reports.push(r);
            }
            Err(e) => eprintln!("  exact on {name}: SKIPPED ({e})"),
        }
        for model in [Model::Sgpr, Model::Svgp] {
            match coordinator::run_model(&env.cfg, model, &ds, 0) {
                Ok(r) => {
                    rows.push(vec![
                        format!("{name} (n={})", ds.n_train()),
                        model.name().into(),
                        format!("{:.3}", r.rmse),
                        format!("{:.1}s", r.train_seconds),
                    ]);
                    reports.push(r);
                }
                Err(e) => eprintln!("  {} on {name}: SKIPPED ({e})", model.name()),
            }
        }
    }

    coordinator::print_table(
        "Table 5 — exact GP with plain Adam vs approximations (paper: exact \
         still wins; RMSE random-guess = 1)",
        &["dataset", "model", "RMSE", "train"],
        &rows,
    );
    if let Ok(p) = coordinator::write_results(&env.cfg, "table5_adam100", &reports) {
        eprintln!("wrote {p:?}");
    }
}
