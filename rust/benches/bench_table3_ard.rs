//! Table 3/4 (appendix): independent lengthscales per dimension (ARD).
//!
//! Paper shape: exact GPs remain generally more accurate than SGPR/SVGP
//! with ARD kernels; training times in the same regime as Table 2.

use exactgp::bench_harness::BenchEnv;
use exactgp::coordinator::{self, Model};

fn main() {
    let mut env = BenchEnv::from_env(&["bike", "kin40k", "protein"]);
    env.cfg.ard = true;
    // The compiled ARD baseline menu (aot.py): SGPR m=128, SVGP m=256.
    env.cfg.sgpr_m = 128;
    env.cfg.svgp_m = 256;

    let models = [Model::ExactBbmm, Model::Sgpr, Model::Svgp];
    let mut rows = Vec::new();
    let mut reports = Vec::new();
    for name in &env.datasets {
        let Ok(ds) = coordinator::load_dataset(&env.cfg, name, 0) else { continue };
        let mut cells = vec![format!("{name} (n={}, d={})", ds.n_train(), ds.d)];
        let mut times = vec![];
        for model in &models {
            match coordinator::run_model(&env.cfg, *model, &ds, 0) {
                Ok(r) => {
                    cells.push(format!("{:.3}", r.rmse));
                    cells.push(format!("{:.3}", r.nll));
                    times.push(format!("{:.1}s", r.train_seconds));
                    reports.push(r);
                }
                Err(e) => {
                    eprintln!("  {} on {name}: SKIPPED ({e})", model.name());
                    cells.push("-".into());
                    cells.push("-".into());
                    times.push("-".into());
                }
            }
        }
        cells.extend(times);
        rows.push(cells);
    }

    coordinator::print_table(
        "Table 3/4 — ARD (independent lengthscales): RMSE | NLL | train time",
        &[
            "dataset",
            "exact RMSE", "exact NLL",
            "sgpr RMSE", "sgpr NLL",
            "svgp RMSE", "svgp NLL",
            "t(exact)", "t(sgpr)", "t(svgp)",
        ],
        &rows,
    );
    if let Ok(p) = coordinator::write_results(&env.cfg, "table3_ard", &reports) {
        eprintln!("wrote {p:?}");
    }
}
