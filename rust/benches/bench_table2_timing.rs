//! Table 2: training time, #workers ("GPUs"), kernel partitions p,
//! precomputation time, and 1,000-point prediction latency.
//!
//! Paper shape to reproduce: exact-GP prediction from warm caches is
//! sub-second and comparable to the approximate methods even where
//! training was much slower.

use exactgp::bench_harness::BenchEnv;
use exactgp::coordinator::{self, Model};

fn main() {
    let env = BenchEnv::from_env(&["poletele", "bike", "kin40k", "3droad"]);
    let models = [Model::ExactBbmm, Model::Sgpr, Model::Svgp];
    let mut rows = Vec::new();
    let mut reports = Vec::new();

    for name in &env.datasets {
        let Ok(ds) = coordinator::load_dataset(&env.cfg, name, 0) else {
            continue;
        };
        for model in &models {
            match coordinator::run_model(&env.cfg, *model, &ds, 0) {
                Ok(r) => {
                    let p = r
                        .extra
                        .iter()
                        .find(|(k, _)| k == "partitions")
                        .map(|(_, v)| format!("{v:.0}"))
                        .unwrap_or_else(|| "-".into());
                    rows.push(vec![
                        format!("{name} (n={})", ds.n_train()),
                        model.name().into(),
                        format!("{:.1}s", r.train_seconds),
                        format!("{}", env.cfg.workers),
                        p,
                        format!("{:.2}s", r.precompute_seconds),
                        format!("{:.0}ms", r.predict_seconds * 1e3),
                    ]);
                    reports.push(r);
                }
                Err(e) => eprintln!("  {} on {name}: SKIPPED ({e})", model.name()),
            }
        }
    }

    coordinator::print_table(
        "Table 2 — timing (train | precompute | 1k predictions from warm caches)",
        &["dataset", "model", "train", "#workers", "p", "precompute", "predict(1k)"],
        &rows,
    );
    if let Ok(p) = coordinator::write_results(&env.cfg, "table2_timing", &reports) {
        eprintln!("wrote {p:?}");
    }
}
