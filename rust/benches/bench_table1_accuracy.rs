//! Table 1: RMSE and NLL of exact GPs (BBMM) vs SGPR (m=512) vs SVGP
//! (m=1024) on the UCI-signature suite, shared lengthscale.
//!
//! Defaults: 4 representative datasets at smoke scale, 1 trial — set
//! EXACTGP_BENCH_DATASETS=all, EXACTGP_BENCH_SCALE=default|large|paper and
//! EXACTGP_BENCH_TRIALS=3 for the paper protocol.

use exactgp::bench_harness::BenchEnv;
use exactgp::coordinator::{self, Model};

fn main() {
    let env = BenchEnv::from_env(&["poletele", "bike", "kin40k", "3droad"]);
    let models = [Model::ExactBbmm, Model::Sgpr, Model::Svgp];
    let mut rows = Vec::new();
    let mut reports = Vec::new();

    for name in &env.datasets {
        let mut rmses = vec![vec![]; models.len()];
        let mut nlls = vec![vec![]; models.len()];
        let mut n_train = 0;
        let mut d = 0;
        for trial in 0..env.trials {
            let ds = match coordinator::load_dataset(&env.cfg, name, trial) {
                Ok(ds) => ds,
                Err(e) => {
                    eprintln!("skipping {name}: {e}");
                    continue;
                }
            };
            n_train = ds.n_train();
            d = ds.d;
            for (mi, model) in models.iter().enumerate() {
                match coordinator::run_model(&env.cfg, *model, &ds, trial) {
                    Ok(r) => {
                        rmses[mi].push(r.rmse);
                        nlls[mi].push(r.nll);
                        reports.push(r);
                    }
                    Err(e) => eprintln!("  {} on {name}: SKIPPED ({e})", model.name()),
                }
            }
        }
        let mut cells = vec![format!("{name} (n={n_train}, d={d})")];
        for mi in 0..models.len() {
            cells.push(if rmses[mi].is_empty() {
                "-".into()
            } else {
                exactgp::bench_harness::agg(&rmses[mi])
            });
        }
        for mi in 0..models.len() {
            cells.push(if nlls[mi].is_empty() {
                "-".into()
            } else {
                exactgp::bench_harness::agg(&nlls[mi])
            });
        }
        rows.push(cells);
    }

    coordinator::print_table(
        "Table 1 — RMSE / NLL, shared lengthscale (paper: exact GP best on nearly all)",
        &[
            "dataset",
            "RMSE exact", "RMSE sgpr", "RMSE svgp",
            "NLL exact", "NLL sgpr", "NLL svgp",
        ],
        &rows,
    );
    if let Ok(p) = coordinator::write_results(&env.cfg, "table1_accuracy", &reports) {
        eprintln!("wrote {p:?}");
    }
}
