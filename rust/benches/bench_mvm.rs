//! Micro-benchmark: partitioned kernel MVM throughput across backends and
//! partition counts — the hot path underneath every experiment.
//!
//! Reports wall time per full K(X,X) @ V (V is a t=16 block), effective
//! GFLOP/s (counting the fused dist+cov+matvec tile math), the
//! cached-vs-streaming kernel-block comparison (cold fill, warm replay,
//! bitwise check — summarized to results/BENCH_mvm.json), and the
//! partitioning overhead (p=1 vs p=many at fixed n).

use std::sync::Arc;

use exactgp::bench_harness::{time_fn, BenchEnv};
use exactgp::config::{Backend, Flavor, TransportKind};
use exactgp::coordinator::print_table;
use exactgp::exec::transport::subprocess::SubprocessOptions;
use exactgp::exec::transport::BackendSpec;
use exactgp::exec::{backend_factory, pool::DevicePool, PaddedData, PartitionedKernelOp, TileSpec};
use exactgp::kernels::{Hypers, KernelKind};
use exactgp::linalg::Mat;
use exactgp::metrics::Accounting;
use exactgp::partition::Plan;
use exactgp::util::json::{arr, num, obj, s, Json};
use exactgp::util::rng::Rng;

fn tile_flops(spec: &TileSpec) -> f64 {
    // Per tile: r2 expansion (2 matmul-ish: r*c*(2d+4)) + matern (~8 ops)
    // + matvec (r*c*2t).
    (spec.r * spec.c) as f64 * (2.0 * spec.d as f64 + 12.0 + 2.0 * spec.t as f64)
}

fn main() {
    let env = BenchEnv::from_env(&[]);
    let quick = env.quick;
    let spec = TileSpec::PROD;
    let d = 8;
    let mut rng = Rng::new(3, 0);
    let mut rows = Vec::new();
    let reps = if quick { 1 } else { 3 };

    let ns = env.sizes(&[2048, 8192], &[2048]);

    for &n in &ns {
        let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
        let data = Arc::new(PaddedData::new(&x, d, &spec));
        let v = Mat::from_vec(n, spec.t, rng.normal_vec(n * spec.t));
        let tiles_per_mvm =
            (data.n_pad / spec.r) as f64 * (data.n_pad / spec.c).max(1) as f64;
        let flops = tiles_per_mvm * tile_flops(&spec);

        for (label, backend, flavor) in [
            ("native", Backend::Native, Flavor::Jnp),
            ("pjrt/jnp", Backend::Pjrt, Flavor::Jnp),
            ("pjrt/pallas", Backend::Pjrt, Flavor::Pallas),
        ] {
            let mut cfg = env.cfg.clone();
            cfg.backend = backend;
            cfg.flavor = flavor;
            let Ok(factory) = backend_factory(&cfg, cfg.kernel, false, spec.d, spec) else {
                eprintln!("{label}: backend unavailable, skipping");
                continue;
            };
            let Ok(pool) = DevicePool::new(cfg.workers, factory) else { continue };
            let op = PartitionedKernelOp::square(
                data.clone(),
                Arc::new(pool),
                Plan::with_rows(data.n_pad, data.n_pad, spec.r),
                spec,
                Hypers::default_init(None),
                Arc::new(Accounting::default()),
            );
            let stats = time_fn(if quick { 0 } else { 1 }, reps, || {
                let _ = op.apply_raw(&v);
            });
            rows.push(vec![
                format!("n={n}"),
                label.into(),
                stats.fmt_seconds(),
                format!("{:.2}", flops / stats.min / 1e9),
            ]);
        }
    }

    print_table(
        "MVM throughput (full K(X,X) @ V, t=16 RHS block)",
        &["size", "backend", "time/MVM", "GFLOP/s (best)"],
        &rows,
    );

    // Native worker scaling at the largest n: the acceptance target is
    // >= 2x throughput with 4 workers vs the single-threaded baseline on
    // a multi-core host.
    {
        let n = *ns.last().unwrap_or(&8192);
        let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
        let data = Arc::new(PaddedData::new(&x, d, &spec));
        let v = Mat::from_vec(n, spec.t, rng.normal_vec(n * spec.t));
        let mut rows_w = Vec::new();
        let mut base = f64::NAN;
        for workers in [1usize, 2, 4] {
            let mut cfg = env.cfg.clone();
            cfg.backend = Backend::Native;
            cfg.workers = workers;
            let Ok(factory) = backend_factory(&cfg, cfg.kernel, false, spec.d, spec) else {
                break;
            };
            let Ok(pool) = DevicePool::new(workers, factory) else { break };
            let op = PartitionedKernelOp::square(
                data.clone(),
                Arc::new(pool),
                Plan::with_rows(data.n_pad, data.n_pad, spec.r),
                spec,
                Hypers::default_init(None),
                Arc::new(Accounting::default()),
            );
            let stats = time_fn(if quick { 0 } else { 1 }, reps, || {
                let _ = op.apply_raw(&v);
            });
            if workers == 1 {
                base = stats.mean;
            }
            rows_w.push(vec![
                workers.to_string(),
                stats.fmt_seconds(),
                format!("{:.2}x", base / stats.mean),
            ]);
        }
        print_table(
            &format!("Native MVM scaling with workers (n={n}, t={})", spec.t),
            &["workers", "time/MVM", "speedup vs 1 worker"],
            &rows_w,
        );
    }

    // Cached-vs-streaming sweep (the kernel-block cache): every training
    // step's mBCG solve issues tens of MVMs at fixed hyperparameters, so
    // after the first MVM fills the worker-resident rho blocks, the rest
    // reduce to blocked gemm. Targets: >= 3x warm speedup when the cache
    // fits the budget, bitwise-identical outputs, <= 5% cold overhead.
    {
        let n = if quick { 2048 } else { *ns.last().unwrap_or(&8192) };
        let workers = env.cfg.workers.max(1);
        let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
        let data = Arc::new(PaddedData::new(&x, d, &spec));
        let v = Mat::from_vec(n, spec.t, rng.normal_vec(n * spec.t));
        let mut cfg = env.cfg.clone();
        cfg.backend = Backend::Native;
        cfg.workers = workers;
        // A budget that holds the whole operator resident.
        let full_budget =
            (data.n_pad / spec.r) * (data.n_pad / spec.c).max(1) * spec.r * spec.c * 4;
        let mk_op = |budget: usize| -> PartitionedKernelOp {
            let factory =
                backend_factory(&cfg, cfg.kernel, false, spec.d, spec).expect("native");
            let pool = DevicePool::new(workers, factory).expect("pool");
            PartitionedKernelOp::square(
                data.clone(),
                Arc::new(pool),
                Plan::with_rows(data.n_pad, data.n_pad, (spec.r * 4).min(data.n_pad)),
                spec,
                Hypers::default_init(None),
                Arc::new(Accounting::default()),
            )
            .with_cache_budget(budget)
        };
        let cache_reps = if quick { 2 } else { 3 };
        // Cold: bump the generation before each rep so every measured MVM
        // re-materializes its blocks (what the first solve iteration pays).
        let time_cold = |op: &mut PartitionedKernelOp, reps: usize| -> f64 {
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let h = op.hypers.clone();
                op.set_hypers(h);
                let t0 = std::time::Instant::now();
                let _ = op.apply_raw(&v);
                best = best.min(t0.elapsed().as_secs_f64());
            }
            best
        };
        let mut streaming = mk_op(0);
        let mut cached = mk_op(full_budget);
        let stream_cold = time_cold(&mut streaming, cache_reps);
        let cached_cold = time_cold(&mut cached, cache_reps);
        // Warm: blocks resident from the cold pass; iterations 2..m of a
        // solve see exactly this.
        let stream_warm = time_fn(0, cache_reps, || {
            let _ = streaming.apply_raw(&v);
        })
        .min;
        let cached_warm = time_fn(0, cache_reps, || {
            let _ = cached.apply_raw(&v);
        })
        .min;
        let bitwise = streaming.apply_raw(&v).data == cached.apply_raw(&v).data;
        let speedup = stream_warm / cached_warm;
        let cold_overhead = cached_cold / stream_cold - 1.0;
        // Transport overhead: the identical streaming MVM pushed through
        // the subprocess transport (one OS process per worker, length-
        // prefixed stdio frames). Recording it in BENCH_mvm.json lets the
        // trajectory catch wire-protocol regressions; skipped gracefully
        // when worker processes cannot spawn on the host.
        let sub_warm = {
            let bspec =
                BackendSpec::Native { kernel: cfg.kernel, ard: false, spec, radius: 1.0 };
            let opts = SubprocessOptions {
                worker_bin: Some(env!("CARGO_BIN_EXE_exactgp").into()),
                ..SubprocessOptions::default()
            };
            match DevicePool::with_transport(TransportKind::Subprocess, workers, &bspec, opts)
            {
                Ok(pool) => {
                    let op = PartitionedKernelOp::square(
                        data.clone(),
                        Arc::new(pool),
                        Plan::with_rows(data.n_pad, data.n_pad, (spec.r * 4).min(data.n_pad)),
                        spec,
                        Hypers::default_init(None),
                        Arc::new(Accounting::default()),
                    );
                    Some(
                        time_fn(0, cache_reps, || {
                            let _ = op.apply_raw(&v);
                        })
                        .min,
                    )
                }
                Err(e) => {
                    eprintln!("subprocess transport unavailable, skipping overhead row: {e:#}");
                    None
                }
            }
        };
        let fmt_s = |x: f64| {
            if x < 1e-3 {
                format!("{:.1}us", x * 1e6)
            } else if x < 1.0 {
                format!("{:.1}ms", x * 1e3)
            } else {
                format!("{x:.2}s")
            }
        };
        print_table(
            &format!(
                "Kernel-block cache at n={n} (native, {workers} workers, t={} RHS)",
                spec.t
            ),
            &["mode", "cold MVM", "warm MVM", "warm speedup", "bitwise"],
            &[
                vec![
                    "streaming".into(),
                    fmt_s(stream_cold),
                    fmt_s(stream_warm),
                    "1.00x".into(),
                    "-".into(),
                ],
                vec![
                    "cached".into(),
                    fmt_s(cached_cold),
                    fmt_s(cached_warm),
                    format!("{speedup:.2}x"),
                    bitwise.to_string(),
                ],
            ],
        );
        {
            let mut rows_t = vec![vec![
                "local (threads)".into(),
                fmt_s(stream_warm),
                "1.00x".into(),
            ]];
            if let Some(t) = sub_warm {
                rows_t.push(vec![
                    "subprocess (stdio)".into(),
                    fmt_s(t),
                    format!("{:.2}x", t / stream_warm),
                ]);
            }
            print_table(
                &format!("Transport overhead at n={n} (streaming MVM, {workers} workers)"),
                &["transport", "time/MVM", "vs local"],
                &rows_t,
            );
        }
        // Sparsity sweep: a compact-support kernel on clustered,
        // locality-ordered data lets the bbox proof skip cross-cluster
        // tiles outright — no materialization, no gemm. Measured against
        // a dense Matern-3/2 MVM (the default kernel, never skippable)
        // and against the same Wendland op with skipping force-disabled,
        // at identical tile geometry. Gates (CI runs this in quick mode):
        // skip rate >= 30% on the clustered layout, and the skipping MVM
        // bitwise-equal to the force-dense one.
        let sparsity = {
            let sn = if quick { 6144 } else { 102_400 };
            let k = if quick { 8 } else { 32 }; // clusters, 20 apart on a line
            let d_s = 3;
            let s_radius = 1.0;
            let mut srng = Rng::new(7, 0);
            let mut sx = Vec::with_capacity(sn * d_s);
            for c in 0..k {
                let center = c as f64 * 20.0;
                for _ in 0..sn / k {
                    sx.push(center + 0.5 * srng.normal());
                    sx.push(0.5 * srng.normal());
                    sx.push(0.5 * srng.normal());
                }
            }
            let sdata = Arc::new(PaddedData::new(&sx, d_s, &spec));
            let sv = Mat::from_vec(sn, spec.t, srng.normal_vec(sn * spec.t));
            let shypers = Hypers {
                log_lengthscales: vec![0.0],
                log_outputscale: 0.0,
                log_noise: (0.1f64).ln(),
            };
            let mk = |kernel: KernelKind, force_dense: bool| -> PartitionedKernelOp {
                let mut scfg = env.cfg.clone();
                scfg.backend = Backend::Native;
                scfg.support_radius = s_radius;
                let factory =
                    backend_factory(&scfg, kernel, false, spec.d, spec).expect("native");
                let pool = DevicePool::new(workers, factory).expect("pool");
                PartitionedKernelOp::square(
                    sdata.clone(),
                    Arc::new(pool),
                    Plan::with_rows(sdata.n_pad, sdata.n_pad, (spec.r * 4).min(sdata.n_pad)),
                    spec,
                    shypers.clone(),
                    Arc::new(Accounting::default()),
                )
                .with_force_dense(force_dense)
            };
            let matern = mk(KernelKind::Matern32, false);
            let wend_dense = mk(KernelKind::WendlandC2, true);
            let wend_skip = mk(KernelKind::WendlandC2, false);
            let matern_s = time_fn(0, 1, || {
                let _ = matern.apply_raw(&sv);
            })
            .min;
            let wdense_s = time_fn(0, 1, || {
                let _ = wend_dense.apply_raw(&sv);
            })
            .min;
            let wskip_s = time_fn(0, 1, || {
                let _ = wend_skip.apply_raw(&sv);
            })
            .min;
            // Parity + skip-rate gates on a counted pass.
            let before = wend_skip.acct.snapshot();
            let skip_out = wend_skip.apply_raw(&sv);
            let delta = wend_skip.acct.snapshot().delta(&before);
            let dense_out = wend_dense.apply_raw(&sv);
            let bitwise_sparse = skip_out.data == dense_out.data;
            let skip_rate = delta.tiles_skipped as f64 / delta.tiles_total.max(1) as f64;
            assert!(
                delta.tiles_skipped > 0,
                "sparsity gate: no tile skipped on the clustered layout"
            );
            assert!(
                skip_rate >= 0.3,
                "sparsity gate: skip rate {skip_rate:.2} below the 30% floor"
            );
            assert!(
                bitwise_sparse,
                "sparsity gate: skipping changed MVM bits vs force-dense"
            );
            print_table(
                &format!(
                    "Compact-kernel tile skipping at n={sn} ({k} clusters, radius={s_radius}, \
                     {workers} workers)"
                ),
                &["kernel", "time/MVM", "skip rate", "speedup", "bitwise vs dense"],
                &[
                    vec![
                        "matern32 (dense)".into(),
                        fmt_s(matern_s),
                        "-".into(),
                        "1.00x".into(),
                        "-".into(),
                    ],
                    vec![
                        "wendland_c2 (force-dense)".into(),
                        fmt_s(wdense_s),
                        "0%".into(),
                        format!("{:.2}x", matern_s / wdense_s),
                        "-".into(),
                    ],
                    vec![
                        "wendland_c2 (skipping)".into(),
                        fmt_s(wskip_s),
                        format!("{:.0}%", skip_rate * 100.0),
                        format!("{:.2}x", matern_s / wskip_s),
                        bitwise_sparse.to_string(),
                    ],
                ],
            );
            obj(vec![
                ("n", num(sn as f64)),
                ("clusters", num(k as f64)),
                ("kernel", s("wendland_c2")),
                ("support_radius", num(s_radius)),
                ("tiles_total", num(delta.tiles_total as f64)),
                ("tiles_skipped", num(delta.tiles_skipped as f64)),
                ("skip_rate", num(skip_rate)),
                ("dense_matern_mvm_s", num(matern_s)),
                ("dense_wendland_mvm_s", num(wdense_s)),
                ("sparse_wendland_mvm_s", num(wskip_s)),
                ("speedup_vs_dense_matern", num(matern_s / wskip_s)),
                ("speedup_vs_dense_wendland", num(wdense_s / wskip_s)),
                ("bitwise_vs_force_dense", Json::Bool(bitwise_sparse)),
            ])
        };
        // Persist the perf trajectory: CI uploads results/BENCH_mvm.json.
        let mut fields = vec![
            ("bench", s("bench_mvm")),
            ("mode", s(if quick { "quick" } else { "full" })),
            ("n", num(n as f64)),
            ("workers", num(workers as f64)),
            ("rhs_t", num(spec.t as f64)),
            ("cache_budget_bytes", num(full_budget as f64)),
            ("streaming_cold_s", num(stream_cold)),
            ("streaming_warm_s", num(stream_warm)),
            ("cached_cold_s", num(cached_cold)),
            ("cached_warm_s", num(cached_warm)),
            ("warm_speedup", num(speedup)),
            ("cold_overhead_frac", num(cold_overhead)),
            ("bitwise_identical", Json::Bool(bitwise)),
            (
                "sweep",
                arr(rows.iter().map(|r| {
                    obj(vec![
                        ("size", s(&r[0])),
                        ("backend", s(&r[1])),
                        ("time", s(&r[2])),
                        ("gflops", s(&r[3])),
                    ])
                })),
            ),
        ];
        if let Some(t) = sub_warm {
            fields.push(("subprocess_mvm_s", num(t)));
            fields.push(("subprocess_overhead_frac", num(t / stream_warm - 1.0)));
        }
        fields.push(("sparsity", sparsity));
        let doc = obj(fields);
        if std::fs::create_dir_all(&env.cfg.results_dir).is_ok() {
            let path =
                std::path::Path::new(&env.cfg.results_dir).join("BENCH_mvm.json");
            if let Err(e) = std::fs::write(&path, doc.to_string_pretty()) {
                eprintln!("could not write {}: {e}", path.display());
            } else {
                println!("wrote {}", path.display());
            }
        }
    }

    if quick {
        return; // smoke run: skip the PJRT partition-overhead sweep
    }

    // Partition-count overhead at fixed n (the O(n)-memory knob).
    let n = *ns.last().unwrap_or(&8192);
    let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
    let data = Arc::new(PaddedData::new(&x, d, &spec));
    let v = Mat::from_vec(n, spec.t, rng.normal_vec(n * spec.t));
    let mut rows2 = Vec::new();
    let mut base = f64::NAN;
    for rows_pp in [data.n_pad, data.n_pad / 2, spec.r * 2, spec.r] {
        let plan = Plan::with_rows(data.n_pad, data.n_pad, rows_pp.max(spec.r));
        let p = plan.p();
        let mut cfg = env.cfg.clone();
        cfg.backend = Backend::Pjrt;
        let Ok(factory) = backend_factory(&cfg, cfg.kernel, false, spec.d, spec) else {
            break;
        };
        let Ok(pool) = DevicePool::new(cfg.workers, factory) else { break };
        let op = PartitionedKernelOp::square(
            data.clone(),
            Arc::new(pool),
            plan.clone(),
            spec,
            Hypers::default_init(None),
            Arc::new(Accounting::default()),
        );
        let stats = time_fn(1, 3, || {
            let _ = op.apply_raw(&v);
        });
        if p == 1 {
            base = stats.mean;
        }
        rows2.push(vec![
            format!("p={p}"),
            format!("{}", plan.transient_bytes(spec.t) >> 20),
            stats.fmt_seconds(),
            format!("{:+.1}%", (stats.mean / base - 1.0) * 100.0),
        ]);
    }
    print_table(
        &format!("Partitioning overhead at n={n} (PJRT backend; paper: partitioning trades memory for sequential compute)"),
        &["partitions", "transient MiB", "time/MVM", "vs p=1"],
        &rows2,
    );
}
