//! Figure 1: the initialization ablation — exact GPs trained with the
//! subset-pretrain + 3-Adam-step recipe vs 100 full Adam steps.
//!
//! Paper shape: comparable RMSE at drastically lower training time on
//! large datasets.

use exactgp::bench_harness::BenchEnv;
use exactgp::coordinator::{self, ExactRecipe, Model};

fn main() {
    let mut env = BenchEnv::from_env(&["bike", "kin40k", "3droad"]);
    // 100 Adam steps at paper fidelity is available via
    // EXACTGP_BENCH_FULL_ADAM; default keeps `cargo bench` tractable.
    env.cfg.full_adam_steps =
        exactgp::bench_harness::env_usize("EXACTGP_BENCH_FULL_ADAM").unwrap_or(25);

    let mut rows = Vec::new();
    let mut reports = Vec::new();
    for name in &env.datasets {
        let Ok(ds) = coordinator::load_dataset(&env.cfg, name, 0) else {
            continue;
        };
        for (label, recipe) in [
            ("pretrain + 3 Adam", ExactRecipe::PretrainFinetune),
            (
                &format!("{} Adam (no pretrain)", env.cfg.full_adam_steps),
                ExactRecipe::FullAdam,
            ),
        ] {
            match coordinator::run_model_with_recipe(
                &env.cfg,
                Model::ExactBbmm,
                &ds,
                0,
                recipe,
            ) {
                Ok(mut r) => {
                    rows.push(vec![
                        format!("{name} (n={})", ds.n_train()),
                        label.to_string(),
                        format!("{:.3}", r.rmse),
                        format!("{:.3}", r.nll),
                        format!("{:.1}s", r.train_seconds),
                    ]);
                    r.model = format!("exact-gp[{label}]");
                    reports.push(r);
                }
                Err(e) => eprintln!("  {name} [{label}]: SKIPPED ({e})"),
            }
        }
    }

    coordinator::print_table(
        "Figure 1 — initialization ablation (paper: similar RMSE, much less time)",
        &["dataset", "recipe", "RMSE", "NLL", "train"],
        &rows,
    );
    if let Ok(p) = coordinator::write_results(&env.cfg, "fig1_init", &reports) {
        eprintln!("wrote {p:?}");
    }
}
