//! Figure 4: exact-GP test RMSE as a function of subsampled training-set
//! size, vs SGPR/SVGP trained on the full training set (KEGGU, 3DRoad,
//! Song in the paper).
//!
//! Paper shape: error decreases monotonically with n, and an exact GP
//! with ~1/4 of the data already beats the approximations on all of it.

use exactgp::bench_harness::BenchEnv;
use exactgp::coordinator::{self, Model};
use exactgp::util::json::{num, obj, s, Json};
use exactgp::util::rng::Rng;

fn main() {
    let env = BenchEnv::from_env(&["keggu", "3droad", "song"]);
    let fractions = [0.125, 0.25, 0.5, 1.0];
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();

    for name in &env.datasets {
        let Ok(ds) = coordinator::load_dataset(&env.cfg, name, 0) else { continue };
        let n_full = ds.n_train();

        for &frac in &fractions {
            let n_sub = ((n_full as f64) * frac) as usize;
            let mut rng = Rng::new(17, 0);
            let sub = ds.subsample_train(n_sub.max(64), &mut rng);
            match coordinator::run_model(&env.cfg, Model::ExactBbmm, &sub, 0) {
                Ok(r) => {
                    rows.push(vec![
                        name.clone(),
                        "exact-gp".into(),
                        format!("{} ({:.0}%)", sub.n_train(), frac * 100.0),
                        format!("{:.3}", r.rmse),
                    ]);
                    json_rows.push(obj(vec![
                        ("dataset", s(name)),
                        ("model", s("exact-gp")),
                        ("n_train", num(sub.n_train() as f64)),
                        ("fraction", num(frac)),
                        ("rmse", num(r.rmse)),
                    ]));
                }
                Err(e) => eprintln!("  exact {name} frac={frac}: SKIPPED ({e})"),
            }
        }

        // Approximate baselines on the FULL training set.
        for model in [Model::Sgpr, Model::Svgp] {
            match coordinator::run_model(&env.cfg, model, &ds, 0) {
                Ok(r) => {
                    rows.push(vec![
                        name.clone(),
                        model.name().into(),
                        format!("{n_full} (100%)"),
                        format!("{:.3}", r.rmse),
                    ]);
                    json_rows.push(obj(vec![
                        ("dataset", s(name)),
                        ("model", s(model.name())),
                        ("n_train", num(n_full as f64)),
                        ("fraction", num(1.0)),
                        ("rmse", num(r.rmse)),
                    ]));
                }
                Err(e) => eprintln!("  {} {name}: SKIPPED ({e})", model.name()),
            }
        }
    }

    coordinator::print_table(
        "Figure 4 — RMSE vs subsampled train size (paper: exact GP on 1/4 of the \
         data beats approximations on all of it; error falls monotonically)",
        &["dataset", "model", "n_train", "RMSE"],
        &rows,
    );
    std::fs::create_dir_all(&env.cfg.results_dir).ok();
    let path = std::path::Path::new(&env.cfg.results_dir).join("fig4_subsample.json");
    std::fs::write(
        &path,
        obj(vec![
            ("experiment", s("fig4_subsample")),
            ("rows", Json::Arr(json_rows)),
        ])
        .to_string_pretty(),
    )
    .ok();
    eprintln!("wrote {path:?}");
}
