//! In-tree, dependency-free reimplementation of the subset of the `anyhow`
//! API this workspace uses. The repo must build in fully offline
//! environments (no crates.io access), so the crate is vendored as a path
//! dependency rather than resolved from a registry.
//!
//! Covered surface (everything `rust/src` + examples + benches touch):
//! `Error`, `Result<T>` (with the `E = Error` default), the `anyhow!`,
//! `bail!` and `ensure!` macros, the `Context` trait (on `Result<_, E>`
//! for std errors, on `Result<_, Error>`, and on `Option<_>`), a blanket
//! `From<E: std::error::Error>` so `?` converts freely, and Display with
//! the `{:#}` alternate form printing the whole context chain
//! ("outermost: ...: root cause"), matching real anyhow.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with a default error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

enum Repr {
    Message(String),
    Boxed(Box<dyn StdError + Send + Sync + 'static>),
}

/// A dynamic error with a chain of context messages.
pub struct Error {
    /// Context frames, innermost first (index 0 wraps `repr` directly).
    context: Vec<String>,
    repr: Repr,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { context: Vec::new(), repr: Repr::Message(message.to_string()) }
    }

    /// Wrap a standard error.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { context: Vec::new(), repr: Repr::Boxed(Box::new(error)) }
    }

    /// Attach an outer context message (most recent = outermost).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.context.push(context.to_string());
        self
    }

    /// The chain of messages from outermost context to root cause.
    fn chain_strings(&self) -> Vec<String> {
        let mut parts: Vec<String> = self.context.iter().rev().cloned().collect();
        match &self.repr {
            Repr::Message(m) => parts.push(m.clone()),
            Repr::Boxed(e) => {
                parts.push(e.to_string());
                let mut src = e.source();
                while let Some(s) = src {
                    parts.push(s.to_string());
                    src = s.source();
                }
            }
        }
        parts
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, outermost first, ": "-separated.
            return write!(f, "{}", self.chain_strings().join(": "));
        }
        // `{}`: the outermost message only, like real anyhow.
        match self.context.last() {
            Some(c) => write!(f, "{c}"),
            None => match &self.repr {
                Repr::Message(m) => write!(f, "{m}"),
                Repr::Boxed(e) => write!(f, "{e}"),
            },
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_strings();
        write!(f, "{}", chain[0])?;
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// `?` conversion from any standard error. This coexists with the
// reflexive `From<Error> for Error` because `Error` deliberately does
// not implement `std::error::Error` (the same trick real anyhow uses).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

mod private {
    /// Type-level markers keeping the `Context` impls from unifying.
    pub struct ErrorMarker;
    pub struct OptionMarker;
}

/// Attach context to errors, like `anyhow::Context`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, private::ErrorMarker> for Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, private::OptionMarker> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an `Error` from a format string (or any Display value).
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(::std::format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Error::new(io_err()).context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<i32> {
            let v: i32 = "17".parse()?;
            Ok(v)
        }
        assert_eq!(f().unwrap(), 17);
    }

    #[test]
    fn context_on_result_error_and_option() {
        let r: Result<(), Error> = Err(anyhow!("inner {}", 3));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 3");

        let o: Option<u8> = None;
        let e = o.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");

        let io: Result<(), std::io::Error> = Err(io_err());
        let e = io.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: missing");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).is_err());
        assert!(format!("{}", f(99).unwrap_err()).contains("too big"));
    }
}
