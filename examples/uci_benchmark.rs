//! End-to-end driver (deliverable (b)/EXPERIMENTS.md): the paper's core
//! comparison — exact GP vs SGPR vs SVGP — on any subset of the
//! UCI-signature suite, at a chosen scale.
//!
//!     cargo run --release --example uci_benchmark -- \
//!         --datasets poletele,bike,kin40k --scale default --trials 1
//!
//! Prints Table-1-style rows and writes results/uci_benchmark.json.

use exactgp::cli::Args;
use exactgp::config::Config;
use exactgp::coordinator::{self, Model};
use exactgp::data::synthetic::Scale;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    let mut cfg = Config::load(args.get("config"), &args.overrides()?)?;
    if let Some(s) = args.get("scale") {
        cfg.scale = Scale::parse(s).ok_or_else(|| anyhow::anyhow!("bad scale"))?;
    }
    if let Some(w) = args.get_usize("workers")? {
        cfg.workers = w;
    }
    let trials = args.get_usize("trials")?.unwrap_or(1) as u64;
    let datasets: Vec<String> = args
        .get_or("datasets", "poletele,bike,kin40k")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();

    let models = [Model::ExactBbmm, Model::Sgpr, Model::Svgp];
    let mut reports = Vec::new();
    let mut rows = Vec::new();
    for name in &datasets {
        for trial in 0..trials {
            let ds = coordinator::load_dataset(&cfg, name, trial)?;
            for model in &models {
                match coordinator::run_model(&cfg, *model, &ds, trial) {
                    Ok(r) => {
                        println!(
                            "{name:>14} trial={trial} {:>9}: rmse={:.4} nll={:+.4} train={:.1}s",
                            model.name(),
                            r.rmse,
                            r.nll,
                            r.train_seconds
                        );
                        rows.push(vec![
                            name.clone(),
                            model.name().into(),
                            format!("{:.4}", r.rmse),
                            format!("{:+.4}", r.nll),
                            format!("{:.1}s", r.train_seconds),
                        ]);
                        reports.push(r);
                    }
                    Err(e) => eprintln!("{name} {}: SKIPPED ({e})", model.name()),
                }
            }
        }
    }
    coordinator::print_table(
        "UCI benchmark (Table 1 protocol)",
        &["dataset", "model", "RMSE", "NLL", "train"],
        &rows,
    );
    let path = coordinator::write_results(&cfg, "uci_benchmark", &reports)?;
    eprintln!("wrote {path:?}");
    Ok(())
}
