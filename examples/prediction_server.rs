//! Prediction-serving driver (Table 2's right-hand columns): train an
//! exact GP, precompute the mean/LOVE caches, then serve batched
//! prediction requests and report latency percentiles.
//!
//! The paper's claim: after one-time precomputation, exact GPs answer
//! thousands of predictive means *and variances* in under a second, even
//! when training took hours.
//!
//!     cargo run --release --example prediction_server -- \
//!         --dataset kin40k --scale default --requests 50 --batch 100

use exactgp::cli::Args;
use exactgp::config::Config;
use exactgp::coordinator::make_pool;
use exactgp::data::synthetic::{load, Scale};
use exactgp::gp::exact::{ExactGp, Recipe};
use exactgp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    let mut cfg = Config::default();
    cfg.scale = args.get("scale").and_then(Scale::parse).unwrap_or(Scale::SMOKE);
    if let Some(w) = args.get_usize("workers")? {
        cfg.workers = w;
    }
    let dataset = args.get_or("dataset", "kin40k");
    let requests = args.get_usize("requests")?.unwrap_or(50);
    let batch = args.get_usize("batch")?.unwrap_or(100);

    let ds = load(dataset, cfg.scale, 0).expect("known dataset");
    eprintln!("training exact GP on {dataset} (n={}) ...", ds.n_train());
    let (pool, spec) = make_pool(&cfg, ds.d)?;
    let mut rng = Rng::new(5, 0);
    let mut gp = ExactGp::new(&cfg, cfg.kernel, &ds, pool, spec);
    gp.train(Recipe::paper_default(&cfg), &mut rng)?;
    gp.precompute(&mut rng)?;
    eprintln!(
        "ready: train={:.1}s precompute={:.2}s — serving",
        gp.train_seconds, gp.precompute_seconds
    );

    // Serve `requests` batches of `batch` points sampled from the test
    // split (with replacement), measuring per-request latency.
    let mut latencies = Vec::with_capacity(requests);
    let mut total_rmse_num = 0.0;
    let mut total_points = 0usize;
    for _ in 0..requests {
        let mut xs = Vec::with_capacity(batch * ds.d);
        let mut ys = Vec::with_capacity(batch);
        for _ in 0..batch {
            let i = rng.below(ds.n_test());
            xs.extend_from_slice(&ds.test_x[i * ds.d..(i + 1) * ds.d]);
            ys.push(ds.test_y[i]);
        }
        let t0 = std::time::Instant::now();
        let preds = gp.predict(&xs)?;
        latencies.push(t0.elapsed().as_secs_f64());
        for (p, y) in preds.mean.iter().zip(&ys) {
            total_rmse_num += (p - y) * (p - y);
        }
        total_points += batch;
    }
    // Nearest-rank percentiles; NaN-safe (total_cmp ordering inside).
    let pcts = exactgp::metrics::percentiles(&latencies, &[0.50, 0.90, 0.99]);
    println!("\n== prediction serving ({requests} requests x {batch} points) ==");
    println!("throughput : {:.0} points/s", total_points as f64 / latencies.iter().sum::<f64>());
    println!("latency p50: {:.1} ms", pcts[0] * 1e3);
    println!("latency p90: {:.1} ms", pcts[1] * 1e3);
    println!("latency p99: {:.1} ms", pcts[2] * 1e3);
    println!("served rmse: {:.4}", (total_rmse_num / total_points as f64).sqrt());
    println!("(paper Table 2: 1,000 mean+variance predictions in 6ms-958ms on an RTX 2080 Ti)");
    Ok(())
}
