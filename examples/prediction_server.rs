//! Serving-tier walkthrough: checkpoints in, TCP predictions out.
//!
//! The first run trains a small exact GP and saves a checkpoint; every
//! run after that starts in milliseconds, because serving never trains —
//! the tier hot-loads predict-ready models from checkpoints (paper SS3:
//! after one-time precomputation, means *and* variances are cheap).
//!
//!     cargo run --release --example prediction_server -- \
//!         --dataset bike --scale smoke --requests 200
//!
//! What it shows, end to end:
//!   1. ensure a checkpoint exists (train + save only if missing);
//!   2. start the multi-tenant serving tier on an ephemeral port;
//!   3. speak the wire protocol: `models`, `predict` xN, `stats`;
//!   4. verify the served answers bitwise against a direct
//!      `ExactGp::predict` on the same checkpoint.
//!
//! Point `--connect host:port` at an already-running
//! `exactgp serve --listen ...` to skip step 2 and act as a pure client.

use std::path::PathBuf;

use exactgp::cli::Args;
use exactgp::config::Config;
use exactgp::coordinator::{self, make_pool};
use exactgp::data::synthetic::Scale;
use exactgp::gp::exact::{ExactGp, Recipe};
use exactgp::server::{Client, PredictOutcome, Server};
use exactgp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    let mut cfg = Config::default();
    cfg.scale = args.get("scale").and_then(Scale::parse).unwrap_or(Scale::SMOKE);
    if let Some(w) = args.get_usize("workers")? {
        cfg.workers = w;
    }
    let dataset = args.get_or("dataset", "bike").to_string();
    let requests = args.get_usize("requests")?.unwrap_or(200).max(1);
    let ckpt = PathBuf::from(
        args.get_or("ckpt", &format!("ckpt/example_{dataset}")).to_string(),
    );

    // 1. A checkpoint is the serving tier's unit of deployment: train one
    //    if this is the first run, otherwise reuse it untouched.
    if !exactgp::runtime::checkpoint::exists(&ckpt) {
        let ds = coordinator::load_dataset(&cfg, &dataset, 0)?;
        eprintln!(
            "no checkpoint at {ckpt:?}; training {dataset} once (n={}) ...",
            ds.n_train()
        );
        let (pool, spec) = make_pool(&cfg, ds.d)?;
        let mut rng = Rng::new(5, 0);
        let mut gp = ExactGp::new(&cfg, cfg.kernel, &ds, pool, spec);
        gp.train(Recipe::paper_default(&cfg), &mut rng)?;
        gp.precompute(&mut rng)?;
        gp.save(&ckpt, &ds)?;
        eprintln!(
            "saved {ckpt:?} (train={:.1}s precompute={:.2}s) — future runs skip this",
            gp.train_seconds, gp.precompute_seconds
        );
    }

    // Bitwise reference: what the model answers locally, no network.
    let (gp, ds) = coordinator::load_model(&cfg, &ckpt)?;
    let d = ds.d;
    let pool_points = ds.n_test().min(256).max(1);
    let reference = gp.predict(&ds.test_x[..pool_points * d])?;
    drop(gp);

    // 2. Start the tier (unless pointed at a running one). Port 0 = pick
    //    a free port; `Server` owns the registry, admission control, and
    //    every serve-loop thread.
    // Conditionally held: keeps the in-process tier alive (its Drop joins
    // every server thread) without being read again.
    let _server: Option<Server>;
    let (addr, model_name) = match args.get("connect") {
        Some(addr) => {
            _server = None;
            (addr.to_string(), args.get_or("model", &dataset).to_string())
        }
        None => {
            cfg.server_listen = "127.0.0.1:0".into();
            let specs = vec![(dataset.clone(), ckpt.clone())];
            let srv = Server::start(&cfg, &specs)?;
            eprintln!("serving tier up on {}", srv.addr());
            let addr = srv.addr().to_string();
            _server = Some(srv);
            (addr, dataset.clone())
        }
    };

    // 3. Speak the protocol.
    let mut client = Client::connect(&addr)?;
    println!("== models ==");
    println!("{}", client.models()?.to_string_pretty());

    let mut latencies = Vec::with_capacity(requests);
    let mut sheds = 0usize;
    for k in 0..requests {
        let qi = k % pool_points;
        let x = ds.test_x[qi * d..(qi + 1) * d].to_vec();
        let t0 = std::time::Instant::now();
        let p = match client.predict(&model_name, x)? {
            PredictOutcome::Answer(p) => p,
            PredictOutcome::Shed(why) => {
                // An overloaded tier says so explicitly; a real client
                // backs off and retries. This workload is sequential, so
                // a shed would mean someone else is hammering the tier.
                sheds += 1;
                eprintln!("shed: {why}");
                continue;
            }
            PredictOutcome::Failed(why) => anyhow::bail!("predict failed: {why}"),
        };
        latencies.push(t0.elapsed().as_secs_f64());
        // 4. The wire adds nothing: served == local, bit for bit.
        assert_eq!(p.mean[0].to_bits(), reference.mean[qi].to_bits());
        assert_eq!(p.var[0].to_bits(), reference.var[qi].to_bits());
    }

    let pcts = exactgp::metrics::percentiles(&latencies, &[0.50, 0.90, 0.99]);
    println!("\n== {} single-point predictions over TCP ==", latencies.len());
    println!("latency p50: {:.2} ms", pcts[0] * 1e3);
    println!("latency p90: {:.2} ms", pcts[1] * 1e3);
    println!("latency p99: {:.2} ms", pcts[2] * 1e3);
    println!("sheds      : {sheds}");
    println!("parity     : bitwise-identical to local predict");

    println!("\n== stats ==");
    println!("{}", client.stats()?.to_string_pretty());
    Ok(())
}
