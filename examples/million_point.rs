//! The headline experiment: exact-GP machinery at n > 10^6.
//!
//! Builds the HouseElectric-signature dataset at FULL paper size
//! (n_train = 1,311,539), plans the O(n)-memory kernel partitioning, and
//! runs real partitioned MVM work through the device pool — demonstrating
//! that the full K (6.9 TB at f32!) is never materialized and that memory
//! stays O(n).
//!
//! On this 1-core CPU testbed a full 1.3M x 1.3M MVM is hours of compute
//! (the paper used 8 V100s and still needed days of training), so by
//! default the driver times a sample of partitions and projects the full
//! MVM / CG-iteration / training cost. Run with `--partitions all` to
//! execute a complete MVM, or `--scale <cap>` to train end to end at a
//! reduced n (e.g. `--scale 16384 --train`).

use std::sync::Arc;

use exactgp::cli::Args;
use exactgp::config::Config;
use exactgp::coordinator::make_pool;
use exactgp::data::synthetic::{generate, spec_by_name, Scale};
use exactgp::exec::{PaddedData, PartitionedKernelOp};
use exactgp::kernels::Hypers;
use exactgp::linalg::Mat;
use exactgp::metrics::Accounting;
use exactgp::partition::Plan;
use exactgp::util::rng::Rng;

fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.1} {}", UNITS[u])
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    let mut cfg = Config::default();
    cfg.scale = args
        .get("scale")
        .and_then(Scale::parse)
        .unwrap_or(Scale::PAPER);
    if let Some(w) = args.get_usize("workers")? {
        cfg.workers = w;
    }

    let spec_ds = spec_by_name("houseelectric").unwrap();
    let n_train_target = cfg.scale.effective_train_n(spec_ds);
    eprintln!(
        "generating houseelectric at n_train={n_train_target} (paper: {}) ...",
        spec_ds.n_train_paper
    );
    let t0 = std::time::Instant::now();
    let raw = generate(spec_ds, cfg.scale, 0);
    let mut rng = Rng::new(1, 0);
    let ds = raw.prepare(32, &mut rng);
    eprintln!(
        "generated + split + whitened {} total rows in {:.1}s",
        ds.n_train() + ds.val_y.len() + ds.n_test(),
        t0.elapsed().as_secs_f64()
    );

    let (pool, spec) = make_pool(&cfg, ds.d)?;
    let data = Arc::new(PaddedData::new(&ds.train_x, ds.d, &spec));
    let n = ds.n_train();
    // Plan with the paper's per-device memory (V100-32GB, minus model/PCG
    // overheads ~ 30 GiB usable): reproduces Table 2's p = 218 for
    // HouseElectric. The strip is a *planning bound* — workers stream
    // tiles, so actual peak memory is the tile, not the strip (printed
    // below). Override with --budget-mb.
    let budget_mb = args.get_usize("budget-mb")?.unwrap_or(30 * 1024);
    let plan = Plan::with_memory_budget(
        data.n_pad,
        data.n_pad,
        budget_mb << 20,
        spec.t,
        spec.r,
    );
    let full_k_bytes = (n as u64) * (n as u64) * 4;
    println!("\n== O(n)-memory partition plan (paper SS3) ==");
    println!("n_train               = {n}");
    println!("full K (never built)  = {}", human_bytes(full_k_bytes));
    println!("partitions p          = {}", plan.p());
    println!("rows per partition    = {}", plan.rows_per_partition);
    println!(
        "strip planning bound   = {} (device budget {} MiB; streamed \
         tile-by-tile, see peak tile below)",
        human_bytes(plan.transient_bytes(spec.t) as u64),
        budget_mb
    );
    println!(
        "X + PCG vectors        = {}",
        human_bytes((data.x.len() * 4 + 6 * n * 8) as u64)
    );

    let acct = Arc::new(Accounting::default());
    let hypers = Hypers {
        log_lengthscales: vec![0.0],
        log_outputscale: 0.0,
        log_noise: (0.1f64).ln(),
    };
    let op = PartitionedKernelOp::square(
        data.clone(),
        pool,
        plan.clone(),
        spec,
        hypers,
        acct.clone(),
    );

    // Time a sample of partitions (or all of them with --partitions all).
    let sample: usize = match args.get("partitions") {
        Some("all") => plan.p(),
        Some(k) => k.parse().unwrap_or(4),
        None => 4.min(plan.p()),
    };
    println!("\n== partitioned MVM ({sample}/{} partitions executed) ==", plan.p());
    let v = Mat::from_vec(n, spec.t, rng.normal_vec(n * spec.t));
    let sub_plan = Plan {
        n_rows: plan.n_rows,
        n_cols: plan.n_cols,
        rows_per_partition: plan.rows_per_partition,
        partitions: plan.partitions[..sample].to_vec(),
    };
    let sub_op = PartitionedKernelOp { plan: sub_plan, ..op };
    let t1 = std::time::Instant::now();
    let out = sub_op.apply_raw(&v);
    let dt = t1.elapsed().as_secs_f64();
    assert!(out.data.iter().take(1000).all(|x| x.is_finite()));
    let per_partition = dt / sample as f64;
    let full_mvm = per_partition * plan.p() as f64;
    let snap = acct.snapshot();
    println!("sampled partitions     : {sample} in {dt:.1}s ({per_partition:.2}s each)");
    println!("projected full MVM     : {full_mvm:.0}s (t={} RHS block)", spec.t);
    println!(
        "projected CG solve     : {:.1} min at 25 iterations",
        full_mvm * 25.0 / 60.0
    );
    println!(
        "projected 3-step train : {:.1} h (paper: 4317s on 8 V100s, p=218)",
        full_mvm * 25.0 * 2.0 * 3.0 / 3600.0
    );
    println!(
        "comm per MVM           : {} to + {} from workers (O(n))",
        human_bytes(snap.bytes_to_device),
        human_bytes(snap.bytes_from_device)
    );
    println!(
        "peak transient tile    : {}",
        human_bytes(snap.peak_tile_bytes)
    );

    if args.flag_present("train") {
        println!("\n== end-to-end training at this scale ==");
        let mut gp = exactgp::gp::exact::ExactGp::new(
            &cfg,
            cfg.kernel,
            &ds,
            exactgp::coordinator::make_pool(&cfg, ds.d)?.0,
            spec,
        );
        gp.train(exactgp::gp::exact::Recipe::paper_default(&cfg), &mut rng)?;
        gp.precompute(&mut rng)?;
        let preds = gp.predict(&ds.test_x)?;
        println!(
            "rmse={:.4} nll={:.4} train={:.0}s precompute={:.0}s",
            preds.rmse(&ds.test_y),
            preds.nll(&ds.test_y),
            gp.train_seconds,
            gp.precompute_seconds
        );
    }
    Ok(())
}
