//! Quickstart: train an exact GP (BBMM) on a small synthetic dataset and
//! make calibrated predictions — the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart
//!
//! Needs `make artifacts` for the PJRT backend; pass `--backend native`
//! to run without artifacts.

use exactgp::cli::Args;
use exactgp::config::Config;
use exactgp::coordinator::make_pool;
use exactgp::data::synthetic::{load, Scale};
use exactgp::gp::exact::{ExactGp, Recipe};
use exactgp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    let mut cfg = Config::default();
    cfg.scale = Scale::SMOKE; // n_train = 1024 — a few seconds end to end
    if let Some(b) = args.get("backend") {
        cfg.backend = exactgp::config::Backend::parse(b)?;
    }

    // 1. A dataset with the paper's Bike signature (n scaled down).
    let ds = load("bike", cfg.scale, 0).expect("known dataset");
    println!("dataset: {} n_train={} d={}", ds.name, ds.n_train(), ds.d);

    // 2. The worker pool — each worker stands in for one GPU and owns its
    //    own PJRT client + compiled HLO artifacts.
    let (pool, spec) = make_pool(&cfg, ds.d)?;

    // 3. Train with the paper's recipe: L-BFGS+Adam pretraining on a
    //    subset, then 3 Adam steps of BBMM (mBCG solves + stochastic
    //    Lanczos quadrature) on the full data.
    let mut rng = Rng::new(42, 0);
    let mut gp = ExactGp::new(&cfg, cfg.kernel, &ds, pool, spec);
    gp.train(Recipe::paper_default(&cfg), &mut rng)?;
    println!(
        "trained: lengthscale={:.3} outputscale={:.3} noise={:.4} ({:.1}s, {} partitions)",
        gp.hypers.log_lengthscales[0].exp(),
        gp.hypers.outputscale(),
        gp.hypers.noise(),
        gp.train_seconds,
        gp.partitions,
    );

    // 4. Precompute the prediction caches (tight solve for the mean,
    //    LOVE cache for variances) — after this, predictions are O(n)
    //    matmuls with no solves.
    gp.precompute(&mut rng)?;
    println!("precompute: {:.2}s", gp.precompute_seconds);

    // 5. Predict with uncertainty.
    let preds = gp.predict(&ds.test_x)?;
    let rmse = preds.rmse(&ds.test_y);
    let nll = preds.nll(&ds.test_y);
    println!("test rmse={rmse:.4} (random guess = 1.0), nll={nll:.4}");

    // 6. Calibration check: ~95% of test targets inside 2-sigma.
    let mut inside = 0;
    for i in 0..ds.n_test() {
        let sd = (preds.var[i] + preds.noise).sqrt();
        if (ds.test_y[i] - preds.mean[i]).abs() <= 2.0 * sd {
            inside += 1;
        }
    }
    println!(
        "calibration: {:.1}% of test points within 2 sigma (expect ~95%)",
        100.0 * inside as f64 / ds.n_test() as f64
    );
    Ok(())
}
