"""L1 correctness: Pallas kernel tiles vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compute layer: hypothesis
sweeps shapes, kernels, modes, and hyperparameter ranges; every property
asserts allclose against the naive pairwise oracle.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import matern as pk
from compile.kernels import ref

KINDS = ["matern32", "rbf"]
MODES = ["shared", "ard"]


def make_inputs(seed, r, c, t, d, mode, scale=1.0):
    rng = np.random.default_rng(seed)
    xr = (rng.normal(size=(r, d)) * scale).astype(np.float32)
    xc = (rng.normal(size=(c, d)) * scale).astype(np.float32)
    v = rng.normal(size=(c, t)).astype(np.float32)
    p = 2 if mode == "shared" else d + 1
    theta = (rng.normal(size=(p,)) * 0.5).astype(np.float32)
    return xr, xc, v, theta


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("flavor", ["jnp", "pallas"])
def test_mvm_matches_oracle(kind, mode, flavor):
    r, c, t, d = 16, 32, 4, 8
    xr, xc, v, theta = make_inputs(0, r, c, t, d, mode)
    got = model.build_mvm(flavor, kind, mode, r, c, t, d)(xr, xc, v, theta)[0]
    want = ref.kernel_mvm_ref(kind, mode, xr, xc, v, theta)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    r=st.sampled_from([1, 4, 16]),
    cb_blocks=st.integers(1, 4),
    t=st.sampled_from([1, 2, 16]),
    d=st.sampled_from([1, 3, 8, 32]),
    kind=st.sampled_from(KINDS),
    mode=st.sampled_from(MODES),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
)
def test_mvm_hypothesis_sweep(seed, r, cb_blocks, t, d, kind, mode, scale):
    """Pallas flavor across a broad (shape, hyper, input-scale) space."""
    cb = 8
    c = cb * cb_blocks
    xr, xc, v, theta = make_inputs(seed, r, c, t, d, mode, scale)
    fn = pk.build_pallas_mvm(kind, mode, r, c, t, d, cb=cb)
    got = fn(xr, xc, v, theta)[0]
    want = ref.kernel_mvm_ref(kind, mode, xr, xc, v, theta)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("flavor", ["jnp", "pallas"])
def test_cross_matches_oracle(kind, mode, flavor):
    r, c, d = 16, 32, 8
    xr, xc, _, theta = make_inputs(3, r, c, 1, d, mode)
    got = model.build_cross(flavor, kind, mode, r, c, d)(xr, xc, theta)[0]
    want = ref.KERNELS[(kind, mode)](xr, xc, theta)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_zero_padding_semantics():
    """Padded V rows are zero => padded columns contribute nothing.

    This is the contract the Rust coordinator relies on instead of masks
    (DESIGN.md SS2 fixed-shape strategy).
    """
    r, c, t, d = 8, 32, 2, 4
    xr, xc, v, theta = make_inputs(7, r, c, t, d, "shared")
    n_real = 20
    v_pad = v.copy()
    v_pad[n_real:] = 0.0
    xc_garbage = xc.copy()
    xc_garbage[n_real:] = 123.0  # arbitrary finite garbage in padded rows
    fn = model.build_mvm("jnp", "matern32", "shared", r, c, t, d)
    got = fn(xr, xc_garbage, v_pad, theta)[0]
    want = ref.kernel_mvm_ref(
        "matern32", "shared", xr, xc[:n_real], v[:n_real], theta
    )
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_zero_distance_is_outputscale():
    """k(x, x) = outputscale exactly, and no NaNs from r=0 (sqrt corner)."""
    d = 5
    x = np.ones((4, d), np.float32)
    theta = np.array([0.3, 0.7], np.float32)
    for kind in KINDS:
        k = np.asarray(ref.KERNELS[(kind, "shared")](x, x, theta))
        np.testing.assert_allclose(k, np.exp(0.7), rtol=1e-6)
        fn = model.build_mvm("pallas", kind, "shared", 4, 8, 1, d)
        xc = np.ones((8, d), np.float32)
        v = np.ones((8, 1), np.float32)
        out = np.asarray(fn(x, xc, v, theta)[0])
        assert np.isfinite(out).all()
        np.testing.assert_allclose(out, 8 * np.exp(0.7), rtol=1e-5)


def test_shared_equals_ard_with_tied_lengthscales():
    r, c, t, d = 8, 16, 2, 6
    xr, xc, v, _ = make_inputs(11, r, c, t, d, "shared")
    log_l, log_os = 0.4, -0.2
    th_s = np.array([log_l, log_os], np.float32)
    th_a = np.array([log_l] * d + [log_os], np.float32)
    for kind in KINDS:
        a = ref.kernel_mvm_ref(kind, "shared", xr, xc, v, th_s)
        b = ref.kernel_mvm_ref(kind, "ard", xr, xc, v, th_a)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_kernel_matrix_is_psd():
    """K(X, X) + small jitter must be positive semi-definite."""
    rng = np.random.default_rng(5)
    x = rng.normal(size=(40, 6)).astype(np.float32)
    theta = np.array([0.0, 0.0], np.float32)
    for kind in KINDS:
        k = np.asarray(ref.KERNELS[(kind, "shared")](x, x, theta), np.float64)
        w = np.linalg.eigvalsh(k + 1e-5 * np.eye(40))
        assert w.min() > 0, (kind, w.min())
