"""AOT pipeline: the artifact plan lowers, manifests are consistent, and
HLO text contains no custom-calls (the xla_extension 0.5.1 constraint that
drove linalg_jax.py — see DESIGN.md).
"""

import json
import os

import pytest

from compile import aot


def test_quick_plan_lowers_and_is_custom_call_free(tmp_path):
    arts = aot.plan("quick")
    assert len(arts) >= 5
    import jax

    for name, fn, argspecs, meta in arts[:4]:  # subset: keep test fast
        lowered = jax.jit(fn).lower(*argspecs)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text
        assert "custom-call" not in text, f"{name} contains a custom-call"


def test_full_plan_is_larger_and_unique():
    quick = aot.plan("quick")
    full = aot.plan("full")
    assert len(full) > len(quick)
    names = [a[0] for a in full]
    assert len(names) == len(set(names)), "duplicate artifact names"


def test_plan_covers_paper_requirements():
    """The experiment suite needs: shared+ard matern tiles with grads,
    an m-menu for fig3 sweeps, SGPR n-pad menu, ARD baselines."""
    full = aot.plan("full")
    metas = [a[3] for a in full]

    def have(**kw):
        return any(all(m.get(k) == v for k, v in kw.items()) for m in metas)

    assert have(entry="mvm", kind="matern32", mode="shared", flavor="pallas")
    assert have(entry="mvmgrad", kind="matern32", mode="ard", flavor="jnp")
    assert have(entry="mvm", kind="rbf", mode="shared", flavor="jnp")
    assert have(entry="svgp", m=1024)
    assert have(entry="svgp", m=16)
    assert have(entry="sgpr", m=512, n=4096)
    assert have(entry="sgpr", mode="ard")
    # d=8 fast tiles for low-dimensional datasets
    assert have(entry="mvm", d=8)


def test_existing_manifest_consistent_with_files():
    """If artifacts/ has been built, every manifest entry's file exists and
    parses as HLO-ish text."""
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art_dir, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built")
    with open(manifest_path) as f:
        manifest = json.load(f)
    assert manifest["artifacts"], "empty manifest"
    for a in manifest["artifacts"]:
        p = os.path.join(art_dir, a["file"])
        assert os.path.exists(p), f"missing {a['file']}"
        head = open(p).read(4096)
        assert "HloModule" in head, f"{a['file']} is not HLO text"
        assert "custom-call" not in open(p).read(), f"{a['file']} has custom-call"
