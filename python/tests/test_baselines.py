"""SGPR / SVGP objective correctness (L2 layer for the paper's baselines).

SGPR's collapsed bound is checked against a dense direct computation of
the Titsias objective; SVGP's ELBO is checked against its defining parts
and against SGPR's bound at the optimum of q (they coincide when q(u) is
the optimal Gaussian). Gradients are validated against finite differences.
"""

import numpy as np
import numpy.linalg as la
import pytest

from compile import sgpr, svgp
from compile.kernels import ref


def setup(seed=1, m=6, n=14, d=3):
    rng = np.random.default_rng(seed)
    z = rng.normal(size=(m, d)).astype(np.float32)
    th = np.array([0.1, 0.2, np.log(0.3)], np.float32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = rng.normal(size=(n,)).astype(np.float32)
    return z, th, x, y


def dense_sgpr_bound(z, th, x, y, jitter=sgpr.JITTER):
    m, n = z.shape[0], x.shape[0]
    os_, s2 = float(np.exp(th[1])), float(np.exp(th[2]))
    kzz = np.asarray(ref.matern32(z, z, th[:2]), np.float64) + jitter * np.eye(m)
    kzx = np.asarray(ref.matern32(z, x, th[:2]), np.float64)
    q = kzx.T @ la.solve(kzz, kzx)
    s = q + s2 * np.eye(n)
    return float(
        0.5 * (n * np.log(2 * np.pi) + la.slogdet(s)[1] + y @ la.solve(s, y))
        + 0.5 * (os_ * n - np.trace(q)) / s2
    )


def test_sgpr_bound_matches_dense():
    z, th, x, y = setup()
    mask = np.ones(x.shape[0], np.float32)
    loss, _, _ = sgpr.build_sgpr_step("matern32", "shared", z.shape[0], x.shape[0], z.shape[1])(
        z, th, x, y, mask
    )
    want = dense_sgpr_bound(z, th, x, y)
    assert abs(float(loss) - want) < 1e-3 * abs(want)


def test_sgpr_mask_equivalent_to_dropping_rows():
    z, th, x, y = setup(seed=2, n=16)
    n_real = 10
    mask = np.zeros(x.shape[0], np.float32)
    mask[:n_real] = 1.0
    fn_full = sgpr.build_sgpr_step("matern32", "shared", z.shape[0], x.shape[0], z.shape[1])
    loss_masked = float(fn_full(z, th, x, y, mask)[0])
    fn_small = sgpr.build_sgpr_step("matern32", "shared", z.shape[0], n_real, z.shape[1])
    loss_small = float(
        fn_small(z, th, x[:n_real], y[:n_real], np.ones(n_real, np.float32))[0]
    )
    assert abs(loss_masked - loss_small) < 1e-3 * (1 + abs(loss_small))


def test_sgpr_gradients_match_finite_differences():
    z, th, x, y = setup(seed=3)
    mask = np.ones(x.shape[0], np.float32)
    fn = sgpr.build_sgpr_step("matern32", "shared", z.shape[0], x.shape[0], z.shape[1])
    loss, gz, gt = fn(z, th, x, y, mask)
    eps = 1e-3
    for i in range(len(th)):
        tp, tm = th.copy(), th.copy()
        tp[i] += eps
        tm[i] -= eps
        fd = (float(fn(z, tp, x, y, mask)[0]) - float(fn(z, tm, x, y, mask)[0])) / (2 * eps)
        assert abs(fd - float(np.asarray(gt)[i])) < 2e-2 * (1 + abs(fd)), (i, fd, gt)
    # Spot-check two Z coordinates.
    for (a, b) in [(0, 0), (2, 1)]:
        zp, zm = z.copy(), z.copy()
        zp[a, b] += eps
        zm[a, b] -= eps
        fd = (float(fn(zp, th, x, y, mask)[0]) - float(fn(zm, th, x, y, mask)[0])) / (2 * eps)
        assert abs(fd - float(np.asarray(gz)[a, b])) < 2e-2 * (1 + abs(fd))


def test_svgp_elbo_lower_bounds_sgpr_bound():
    """The collapsed (SGPR) bound is the max over q of the SVGP ELBO, so
    any q gives ELBO <= -sgpr_loss (full-batch, same Z/theta)."""
    z, th, x, y = setup(seed=4, n=12)
    m, n, d = z.shape[0], x.shape[0], z.shape[1]
    mu = np.zeros(m, np.float32)
    lraw = np.zeros((m, m), np.float32)
    elbo = float(
        svgp.build_svgp_step("matern32", "shared", m, n, d)(
            z, mu, lraw, th, x, y, np.float32(1.0)
        )[0]
    )
    sgpr_loss = dense_sgpr_bound(z, th, x, y)
    assert elbo <= -sgpr_loss + 1e-3, (elbo, -sgpr_loss)


def test_svgp_gradients_match_finite_differences():
    z, th, x, y = setup(seed=5, n=8)
    m, n, d = z.shape[0], x.shape[0], z.shape[1]
    rng = np.random.default_rng(0)
    mu = rng.normal(size=(m,)).astype(np.float32) * 0.2
    lraw = (np.tril(rng.normal(size=(m, m)), -1) * 0.1).astype(np.float32)
    fn = svgp.build_svgp_step("matern32", "shared", m, n, d)
    scale = np.float32(1.0)
    out = fn(z, mu, lraw, th, x, y, scale)
    g_mu = np.asarray(out[2])
    eps = 1e-3
    for i in [0, m // 2]:
        mp, mm = mu.copy(), mu.copy()
        mp[i] += eps
        mm[i] -= eps
        # gradients are of -ELBO
        fd = (-float(fn(z, mp, lraw, th, x, y, scale)[0])
              + float(fn(z, mm, lraw, th, x, y, scale)[0])) / (2 * eps)
        assert abs(fd - g_mu[i]) < 2e-2 * (1 + abs(fd)), (i, fd, g_mu[i])


def test_predict_refs_consistent_with_exact_gp_when_z_equals_x():
    """With Z = X, both SGPR and SVGP-at-optimum predictive means collapse
    to the exact GP mean."""
    rng = np.random.default_rng(8)
    n, d = 10, 2
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = rng.normal(size=(n,)).astype(np.float32)
    th = np.array([0.0, 0.0, np.log(0.2)], np.float32)
    xs = rng.normal(size=(5, d)).astype(np.float32)
    mean, var = sgpr.sgpr_predict_ref("matern32", "shared", x, th, x, y, xs)
    # Exact GP:
    k = np.asarray(ref.matern32(x, x, th[:2]), np.float64) + 0.2 * np.eye(n)
    ks = np.asarray(ref.matern32(x, xs, th[:2]), np.float64)
    want = ks.T @ la.solve(k, y.astype(np.float64))
    np.testing.assert_allclose(np.asarray(mean), want, rtol=5e-2, atol=5e-2)
    assert np.all(np.asarray(var) >= 0)
