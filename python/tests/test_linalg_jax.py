"""Custom-call-free linalg (compile/linalg_jax.py) vs jax references.

These ops are what let the SGPR/SVGP artifacts run under xla_extension
0.5.1 (no LAPACK custom-calls); they must match jnp.linalg / jax.scipy in
both values and gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.scipy.linalg import solve_triangular

from compile import linalg_jax as lj


def spd(m, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    g = rng.normal(size=(m, m + 2)).astype(dtype)
    return g @ g.T + 0.5 * np.eye(m, dtype=dtype)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 24), seed=st.integers(0, 10_000))
def test_cholesky_matches_reference(m, seed):
    a = spd(m, seed)
    got = np.asarray(lj.cholesky(a))
    want = np.asarray(jnp.linalg.cholesky(a))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 20), k=st.integers(1, 5), seed=st.integers(0, 10_000))
def test_triangular_solves_match_reference(m, k, seed):
    rng = np.random.default_rng(seed)
    l = np.tril(rng.normal(size=(m, m))).astype(np.float32) + 2.0 * np.eye(m, dtype=np.float32)
    b = rng.normal(size=(m, k)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(lj.solve_lower(l, b)),
        np.asarray(solve_triangular(l, b, lower=True)),
        rtol=2e-4, atol=2e-4,
    )
    np.testing.assert_allclose(
        np.asarray(lj.solve_upper(l.T.copy(), b)),
        np.asarray(solve_triangular(l.T.copy(), b, lower=False)),
        rtol=2e-4, atol=2e-4,
    )


def test_cholesky_gradient_matches_reference():
    m = 10
    a = spd(m, 3)

    def f(chol):
        def inner(a):
            l = chol(a)
            return jnp.sum(jnp.sin(l) * (1.0 + jnp.arange(m)[None, :]))
        return inner

    ga = np.asarray(jax.grad(f(jnp.linalg.cholesky))(a))
    gb = np.asarray(jax.grad(f(lj.cholesky))(a))
    sym = lambda g: (g + g.T) / 2
    np.testing.assert_allclose(sym(ga), sym(gb), rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("argn", [0, 1])
def test_solve_gradients_match_reference(argn):
    m, k = 9, 3
    rng = np.random.default_rng(7)
    l = np.tril(rng.normal(size=(m, m))).astype(np.float32) + 3.0 * np.eye(m, dtype=np.float32)
    b = rng.normal(size=(m, k)).astype(np.float32)

    def g_ref(l, b):
        return jnp.sum(jnp.cos(solve_triangular(l, b, lower=True)))

    def g_got(l, b):
        return jnp.sum(jnp.cos(lj.solve_lower(l, b)))

    gr = np.tril(np.asarray(jax.grad(g_ref, argn)(l, b)))
    gg = np.tril(np.asarray(jax.grad(g_got, argn)(l, b)))
    np.testing.assert_allclose(gr, gg, rtol=1e-3, atol=1e-5)


def test_vector_rhs_supported():
    m = 8
    a = spd(m, 11)
    l = np.asarray(lj.cholesky(a))
    b = np.random.default_rng(1).normal(size=(m,)).astype(np.float32)
    x = np.asarray(lj.solve_lower(l, b))
    assert x.shape == (m,)
    np.testing.assert_allclose(l @ x, b, rtol=1e-4, atol=1e-4)


def test_logdet_identity():
    m = 12
    a = spd(m, 13)
    l = lj.cholesky(a)
    logdet = 2.0 * float(jnp.sum(jnp.log(jnp.diag(l))))
    want = float(np.linalg.slogdet(np.asarray(a, np.float64))[1])
    assert abs(logdet - want) < 1e-3 * abs(want)
