"""L2: SVGP (Hensman et al. 2013/15) minibatch ELBO + gradients.

The paper's second baseline: stochastic variational GP with m = 1024
inducing points and minibatch size 1024, trained with Adam. The whole
step computation — ELBO and gradients w.r.t. all parameters — is one AOT
artifact (jax.grad at trace time); the Rust coordinator owns the Adam loop,
minibatch sampling, and parameter state.

Parameterization:
    Z      (M, D)  inducing locations
    mu     (M,)    variational mean
    l_raw  (M, M)  variational scale: S = L L^T,
                   L = tril(l_raw, -1) + diag(exp(diag(l_raw)))
    theta  (P,)    [log_l | log_l_0..log_l_{d-1}, log_os, log_noise]

Whitened data assumed (the data pipeline whitens); jitter 1e-4 on K_ZZ.
"""

import jax
import jax.numpy as jnp
from .linalg_jax import cholesky as _chol, solve_lower as _slo, solve_upper as _sup

from .model import _r2, _rho

JITTER = 1.0e-4
LOG2PI = 1.8378770664093453


def _kernel_parts(kind, mode, d, theta):
    """Split theta into (inv_lengthscales row, outputscale, noise var)."""
    if mode == "shared":
        inv = jnp.exp(-theta[0]) * jnp.ones((1, d))
        os, s2 = jnp.exp(theta[1]), jnp.exp(theta[2])
    else:
        inv = jnp.exp(-theta[:d])[None, :]
        os, s2 = jnp.exp(theta[d]), jnp.exp(theta[d + 1])
    return inv, os, s2


def _kmat(kind, a_s, b_s, os):
    return os * _rho(kind, _r2(a_s, b_s))


def elbo(kind, mode, z, mu, l_raw, theta, xb, yb, data_scale):
    """The evidence lower bound for one minibatch (to be maximized)."""
    m, d = z.shape
    inv, os, s2 = _kernel_parts(kind, mode, d, theta)

    z_s = z * inv
    x_s = xb * inv
    kzz = _kmat(kind, z_s, z_s, os) + JITTER * jnp.eye(m)
    kzx = _kmat(kind, z_s, x_s, os)  # (M, B)

    lz = _chol(kzz)
    a = _slo(lz, kzx)  # Lz^{-1} Kzx
    alpha = _slo(lz, mu)  # Lz^{-1} mu
    mean_f = a.T @ alpha  # (B,)

    # q(f_i) variance: k_ii - a_i^T a_i + || L^T Kzz^{-1} kz_i ||^2
    ktilde = jnp.maximum(os - jnp.sum(a * a, axis=0), 0.0)
    w = _sup(lz.T, a)  # Kzz^{-1} Kzx  (M, B)
    l = jnp.tril(l_raw, -1) + jnp.diag(jnp.exp(jnp.diag(l_raw)))
    u = l.T @ w  # (M, B)
    quad = jnp.sum(u * u, axis=0)

    resid = yb - mean_f
    ell = -0.5 * (LOG2PI + jnp.log(s2)) - (resid * resid + ktilde + quad) / (
        2.0 * s2
    )

    # KL(q(u) || p(u))
    cc = _slo(lz, l)
    tr_term = jnp.sum(cc * cc)
    logdet_kzz = 2.0 * jnp.sum(jnp.log(jnp.diag(lz)))
    logdet_s = 2.0 * jnp.sum(jnp.diag(l_raw))
    kl = 0.5 * (
        tr_term + jnp.sum(alpha * alpha) - m + logdet_kzz - logdet_s
    )

    return data_scale * jnp.sum(ell) - kl


def build_svgp_step(kind, mode, m, b, d):
    """fn(z, mu, l_raw, theta, xb, yb, data_scale)
    -> (elbo, g_z, g_mu, g_lraw, g_theta)   [gradients of -ELBO]"""

    def loss(z, mu, l_raw, theta, xb, yb, data_scale):
        return -elbo(kind, mode, z, mu, l_raw, theta, xb, yb, data_scale)

    grad = jax.grad(loss, argnums=(0, 1, 2, 3))

    def fn(z, mu, l_raw, theta, xb, yb, data_scale):
        val = elbo(kind, mode, z, mu, l_raw, theta, xb, yb, data_scale)
        gz, gmu, gl, gth = grad(z, mu, l_raw, theta, xb, yb, data_scale)
        return (val, gz, gmu, gl, gth)

    return fn


def svgp_predict_ref(kind, mode, z, mu, l_raw, theta, xstar):
    """Oracle for the Rust-native SVGP predictor (tests only)."""
    m, d = z.shape
    inv, os, s2 = _kernel_parts(kind, mode, d, theta)
    z_s, x_s = z * inv, xstar * inv
    kzz = _kmat(kind, z_s, z_s, os) + JITTER * jnp.eye(m)
    kzx = _kmat(kind, z_s, x_s, os)
    lz = _chol(kzz)
    a = _slo(lz, kzx)
    alpha = _slo(lz, mu)
    mean = a.T @ alpha
    w = _sup(lz.T, a)
    l = jnp.tril(l_raw, -1) + jnp.diag(jnp.exp(jnp.diag(l_raw)))
    u = l.T @ w
    var = jnp.maximum(os - jnp.sum(a * a, axis=0) + jnp.sum(u * u, axis=0), 0.0)
    return mean, var
