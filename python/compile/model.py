"""L2: the jax compute graph for exact-GP tiles.

Two flavors of every MVM entry point:

* ``pallas`` — the L1 fused kernel from ``kernels/matern.py`` (interpret
  mode; the TPU-shaped BlockSpec schedule, DESIGN.md SS8).
* ``jnp``    — the same math as straight-line jnp, fully fused by XLA-CPU.
  On the CPU testbed this flavor is the fast path; both are AOT-lowered and
  the Rust coordinator selects per config (`runtime.flavor`).

Both flavors fold hyperparameters into the inputs (see matern.py docstring)
so the HLO entry signature is uniform:

    kernel_mvm        (xr (R,D), xc (C,D), v (C,T), theta) -> (KV,)
    kernel_mvm_grads  (...)                      -> (KV, G (NL,R,T))
    cross_kernel      (xr, xc, theta)            -> (K (R,C),)

Noise is never inside a tile: the coordinator adds sigma^2 * v on diagonal
blocks. Row/column padding needs no masks: padded V rows are zero, so their
covariance contributions vanish; padded output rows are ignored by the
coordinator.
"""

import jax.numpy as jnp

from .kernels import matern as pk
from .kernels.matern import SQRT3, _scale_inputs


def _r2(xr_s, xc_s):
    xr2 = jnp.sum(xr_s * xr_s, axis=1, keepdims=True)
    xc2 = jnp.sum(xc_s * xc_s, axis=1, keepdims=True).T
    return jnp.maximum(xr2 + xc2 - 2.0 * xr_s @ xc_s.T, 0.0)


def _rho(kind, r2):
    if kind == "matern32":
        # Double-where guard: sqrt is non-differentiable at 0, and the
        # K_ZZ diagonal hits r2 = 0 exactly — without the guard, jax.grad
        # of the SGPR/SVGP objectives w.r.t. Z is NaN.
        safe = jnp.where(r2 > 0.0, r2, 1.0)
        u = jnp.where(r2 > 0.0, jnp.sqrt(3.0 * safe), 0.0)
        return (1.0 + u) * jnp.exp(-u)
    return jnp.exp(-0.5 * r2)


def build_jnp_mvm(kind, mode, r, c, t, d):
    def fn(xr, xc, v, theta):
        xr_s, xc_s, v_s = _scale_inputs(mode, d, xr, xc, v, theta)
        return (_rho(kind, _r2(xr_s, xc_s)) @ v_s,)

    return fn


def build_jnp_mvm_grads(kind, mode, r, c, t, d):
    def fn(xr, xc, v, theta):
        xr_s, xc_s, v_s = _scale_inputs(mode, d, xr, xc, v, theta)
        r2 = _r2(xr_s, xc_s)
        if kind == "matern32":
            u = jnp.sqrt(3.0 * r2)
            e = jnp.exp(-u)
            rho = (1.0 + u) * e
            w = 3.0 * e
            w_shared = e * (3.0 * r2)
        else:
            rho = jnp.exp(-0.5 * r2)
            e = rho
            w = rho
            w_shared = rho * r2
        kv = rho @ v_s
        if mode == "shared":
            return (kv, (w_shared @ v_s)[None, ...])
        gs = []
        for i in range(d):
            ri = xr_s[:, i : i + 1]
            ci = xc_s[:, i : i + 1].T
            d2 = ri * ri + ci * ci - 2.0 * (ri * ci)
            gs.append((w * d2) @ v_s)
        return (kv, jnp.stack(gs, axis=0))

    return fn


def build_jnp_cross(kind, mode, r, c, d):
    def fn(xr, xc, theta):
        if mode == "shared":
            inv = jnp.exp(-theta[0])
            os = jnp.exp(theta[1])
            xr_s, xc_s = xr * inv, xc * inv
        else:
            inv = jnp.exp(-theta[:d])[None, :]
            os = jnp.exp(theta[d])
            xr_s, xc_s = xr * inv, xc * inv
        return (os * _rho(kind, _r2(xr_s, xc_s)),)

    return fn


def build_mvm(flavor, kind, mode, r, c, t, d):
    if flavor == "pallas":
        return pk.build_pallas_mvm(kind, mode, r, c, t, d)
    return build_jnp_mvm(kind, mode, r, c, t, d)


def build_mvm_grads(flavor, kind, mode, r, c, t, d):
    if flavor == "pallas":
        return pk.build_pallas_mvm_grads(kind, mode, r, c, t, d)
    return build_jnp_mvm_grads(kind, mode, r, c, t, d)


def build_cross(flavor, kind, mode, r, c, d):
    if flavor == "pallas":
        return pk.build_pallas_cross(kind, mode, r, c, d)
    return build_jnp_cross(kind, mode, r, c, d)
