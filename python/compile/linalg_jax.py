"""Custom-call-free dense linear algebra for the AOT path.

jax.numpy's cholesky / triangular_solve lower to LAPACK custom-calls
(API_VERSION_TYPED_FFI) on CPU, which xla_extension 0.5.1 — the XLA behind
the Rust `xla` crate — cannot execute. The SGPR/SVGP artifacts therefore
use these hand-rolled implementations built only from plain HLO ops
(while-loops + masked vector updates), with custom VJPs so jax.grad works
without O(m^3) autodiff memory:

* ``cholesky(a)``        — left-looking, O(m) loop iterations of O(m^2)
                           masked work; VJP per Murray (2016).
* ``solve_lower(l, b)``  — forward substitution; VJP via transposed solves.
* ``solve_upper(u, b)``  — back substitution.

Verified against jnp.linalg / jax.scipy (values and gradients) in
python/tests/test_linalg_jax.py.
"""

import jax
import jax.numpy as jnp
from jax import lax


def _chol_forward(a):
    a = jnp.asarray(a)
    m = a.shape[0]
    idx = jnp.arange(m)

    def body(j, l):
        # row_j = L[j, :j] (mask out k >= j)
        row_j = jnp.where(idx < j, l[j, :], 0.0)
        # c_i = A[i, j] - sum_{k<j} L[i,k] L[j,k]
        c = a[:, j] - l @ row_j
        d = jnp.sqrt(jnp.maximum(c[j], 1e-30))
        col = jnp.where(idx > j, c / d, 0.0)
        l = l.at[:, j].set(col)
        l = l.at[j, j].set(d)
        return l

    return lax.fori_loop(0, m, body, jnp.zeros_like(a))


def _solve_lower_forward(l, b):
    """X = L^{-1} B by forward substitution. b: (m,) or (m, k)."""
    l = jnp.asarray(l)
    b = jnp.asarray(b)
    vec = b.ndim == 1
    bb = b[:, None] if vec else b
    m = l.shape[0]
    idx = jnp.arange(m)

    def body(i, x):
        li = jnp.where(idx < i, l[i, :], 0.0)
        xi = (bb[i, :] - li @ x) / l[i, i]
        return x.at[i, :].set(xi)

    x = lax.fori_loop(0, m, body, jnp.zeros_like(bb))
    return x[:, 0] if vec else x


def _solve_upper_forward(u, b):
    """X = U^{-1} B by back substitution."""
    u = jnp.asarray(u)
    b = jnp.asarray(b)
    vec = b.ndim == 1
    bb = b[:, None] if vec else b
    m = u.shape[0]
    idx = jnp.arange(m)

    def body(step, x):
        i = m - 1 - step
        ui = jnp.where(idx > i, u[i, :], 0.0)
        xi = (bb[i, :] - ui @ x) / u[i, i]
        return x.at[i, :].set(xi)

    x = lax.fori_loop(0, m, body, jnp.zeros_like(bb))
    return x[:, 0] if vec else x


# ---------------------------------------------------------------------------
# custom VJPs
# ---------------------------------------------------------------------------


@jax.custom_vjp
def cholesky(a):
    """Lower Cholesky factor of SPD `a` (no custom-calls in the lowering)."""
    return _chol_forward(a)


def _chol_fwd(a):
    l = _chol_forward(a)
    return l, l


def _phi(m):
    """Lower triangle with halved diagonal (Murray 2016's Phi)."""
    return jnp.tril(m) - 0.5 * jnp.diag(jnp.diag(m))


def _chol_bwd(l, l_bar):
    # a_bar = 1/2 L^{-T} (Phi + Phi^T) L^{-1},  Phi = phi(L^T L_bar)
    p = _phi(l.T @ l_bar)
    sym = p + p.T
    # w = L^{-T} sym  -> solve L^T w = sym (upper solve with U = L^T)
    w = _solve_upper_forward(l.T, sym)
    # a_bar = 1/2 w L^{-1}  -> solve a_bar L = w/2, i.e. L^T a_bar^T = w^T/2
    a_bar_t = _solve_upper_forward(l.T, w.T / 2.0)
    return (a_bar_t.T,)


cholesky.defvjp(_chol_fwd, _chol_bwd)


@jax.custom_vjp
def solve_lower(l, b):
    """X = L^{-1} B for lower-triangular L."""
    return _solve_lower_forward(l, b)


def _sl_fwd(l, b):
    x = _solve_lower_forward(l, b)
    return x, (l, x)


def _sl_bwd(res, x_bar):
    l, x = res
    b_bar = _solve_upper_forward(l.T, x_bar)
    if x.ndim == 1:
        l_bar = -jnp.tril(jnp.outer(b_bar, x))
    else:
        l_bar = -jnp.tril(b_bar @ x.T)
    return (l_bar, b_bar)


solve_lower.defvjp(_sl_fwd, _sl_bwd)


@jax.custom_vjp
def solve_upper(u, b):
    """X = U^{-1} B for upper-triangular U."""
    return _solve_upper_forward(u, b)


def _su_fwd(u, b):
    x = _solve_upper_forward(u, b)
    return x, (u, x)


def _su_bwd(res, x_bar):
    u, x = res
    b_bar = _solve_lower_forward(u.T, x_bar)
    if x.ndim == 1:
        u_bar = -jnp.triu(jnp.outer(b_bar, x))
    else:
        u_bar = -jnp.triu(b_bar @ x.T)
    return (u_bar, b_bar)


solve_upper.defvjp(_su_fwd, _su_bwd)
