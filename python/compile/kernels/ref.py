"""Pure-jnp correctness oracle for the kernel tiles.

This module is the *specification*: naive, obviously-correct pairwise
formulas, differentiated with jax autodiff. The Pallas kernels in
``matern.py`` / ``rbf.py`` and the fused jnp flavors in ``model.py`` are
tested against these functions (pytest + hypothesis in python/tests/).

Conventions (shared with the Rust side — keep in sync with
rust/src/kernels/mod.rs):

* ``theta_shared = [log_lengthscale, log_outputscale]`` — outputscale is the
  *variance* s^2, not the std.
* ``theta_ard    = [log_l_0, ..., log_l_{d-1}, log_outputscale]``.
* Observational noise sigma^2 is NOT part of any kernel tile; the Rust
  coordinator adds ``sigma^2 * v_i`` on diagonal blocks.
* Matern-3/2:  k(r) = s^2 (1 + u) exp(-u),  u = sqrt(3) r / l.
* RBF:         k(r) = s^2 exp(-r^2 / (2 l^2)).
"""

import jax
import jax.numpy as jnp

SQRT3 = 1.7320508075688772


def sq_dists(xr, xc, inv_ls=None):
    """Pairwise squared distances (R, C), optionally ARD-weighted.

    ``inv_ls``: per-dimension 1/l_i (d,). If None, unit weights.
    Naive quadratic formula — the oracle; the fused kernels use the
    ||a||^2 + ||b||^2 - 2ab expansion instead.
    """
    if inv_ls is not None:
        xr = xr * inv_ls[None, :]
        xc = xc * inv_ls[None, :]
    diff = xr[:, None, :] - xc[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def matern32(xr, xc, theta):
    """Shared-lengthscale Matern-3/2 covariance tile (R, C)."""
    log_l, log_os = theta[0], theta[1]
    l = jnp.exp(log_l)
    os = jnp.exp(log_os)
    r = jnp.sqrt(jnp.maximum(sq_dists(xr, xc), 0.0))
    u = SQRT3 * r / l
    return os * (1.0 + u) * jnp.exp(-u)


def matern32_ard(xr, xc, theta):
    """ARD Matern-3/2 covariance tile. theta = [log_l_0..log_l_{d-1}, log_os]."""
    d = xr.shape[-1]
    inv_ls = jnp.exp(-theta[:d])
    os = jnp.exp(theta[d])
    r = jnp.sqrt(jnp.maximum(sq_dists(xr, xc, inv_ls), 0.0))
    u = SQRT3 * r
    return os * (1.0 + u) * jnp.exp(-u)


def rbf(xr, xc, theta):
    """Shared-lengthscale RBF covariance tile (R, C)."""
    log_l, log_os = theta[0], theta[1]
    inv_l = jnp.exp(-log_l)
    os = jnp.exp(log_os)
    r2 = jnp.maximum(sq_dists(xr, xc), 0.0)
    return os * jnp.exp(-0.5 * r2 * inv_l * inv_l)


def rbf_ard(xr, xc, theta):
    d = xr.shape[-1]
    inv_ls = jnp.exp(-theta[:d])
    os = jnp.exp(theta[d])
    r2 = jnp.maximum(sq_dists(xr, xc, inv_ls), 0.0)
    return os * jnp.exp(-0.5 * r2)


KERNELS = {
    ("matern32", "shared"): matern32,
    ("matern32", "ard"): matern32_ard,
    ("rbf", "shared"): rbf,
    ("rbf", "ard"): rbf_ard,
}


def kernel_mvm_ref(kind, mode, xr, xc, v, theta):
    """Oracle for the fused MVM tile: K(xr, xc) @ v -> (R, T)."""
    return KERNELS[(kind, mode)](xr, xc, theta) @ v


def kernel_mvm_grads_ref(kind, mode, xr, xc, v, theta):
    """Oracle for the gradient-MVM tile.

    Returns (KV, G) where G stacks d/dlog_l_i [K] V over the lengthscale
    parameters:
      shared: KV (R,T), G (1, R, T)
      ard:    KV (R,T), G (d, R, T)

    The log-outputscale derivative is omitted because
    d/dlog_os [K] V == K V exactly (K = os * rho), and the noise derivative
    is the identity — both are recovered for free by the coordinator.
    """
    kfn = KERNELS[(kind, mode)]
    nl = 1 if mode == "shared" else xr.shape[-1]

    def mv(th):
        return kfn(xr, xc, th) @ v

    kv = mv(theta)
    jac = jax.jacfwd(mv)(theta)  # (R, T, P)
    g = jnp.moveaxis(jac[..., :nl], -1, 0)  # (nl, R, T)
    return kv, g
