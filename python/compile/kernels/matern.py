"""L1 Pallas kernels: fused distance -> covariance -> matvec tiles.

The paper's GPU strategy materializes each (n/p) x n kernel partition in HBM,
multiplies with cuBLAS, and discards it. The TPU rethink (DESIGN.md
SS8 Hardware-Adaptation): never materialize the partition at all. One Pallas
kernel stages X-row/X-col/V blocks HBM->VMEM, computes the covariance tile on
the MXU (the -2*Xr@Xc^T term and the final (R,C)x(C,T) accumulation are both
systolic-array matmuls), applies the Matern/RBF nonlinearity on the VPU, and
accumulates K@V in a VMEM-resident accumulator across the column-block grid.
The K tile exists only in scratchpad.

Scalar-free kernels: all hyperparameters are folded into the *inputs* by the
caller (same jit, same HLO module):

    xr_s = xr * (1/l)   (per-dim 1/l_i for ARD)
    xc_s = xc * (1/l)
    v_s  = v * outputscale

so  K @ v = os * rho(dists(xr_s, xc_s)) @ v = rho(...) @ v_s,  and the
lengthscale-gradient tiles become (Matern-3/2, with u = sqrt(3)*r_scaled):

    d/dlog_l_i [K] v = 3 * e^{-u} .* d_i^2_scaled @ v_s        (ARD)
    d/dlog_l   [K] v =     e^{-u} .* u^2          @ v_s        (shared)

(derivation in DESIGN.md SS6; verified against jax.jacfwd of ref.py).
RBF analogues:  rho = e^{-r^2/2},  d/dlog_l_i = rho .* d_i^2_scaled.

Kernels MUST be lowered with interpret=True for CPU-PJRT execution (real-TPU
lowering emits a Mosaic custom-call the CPU plugin cannot run).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT3 = 1.7320508075688772


def _tile_r2(xr, xc):
    """Squared distances for a tile via the MXU-friendly expansion."""
    xr2 = jnp.sum(xr * xr, axis=1, keepdims=True)  # (R, 1)
    xc2 = jnp.sum(xc * xc, axis=1, keepdims=True).T  # (1, C)
    cross = jnp.dot(xr, xc.T, preferred_element_type=jnp.float32)  # MXU
    return jnp.maximum(xr2 + xc2 - 2.0 * cross, 0.0)


def _rho_and_e(kind, r2):
    """Correlation rho(r2) and the shared exponential factor e.

    Matern-3/2: rho = (1+u) e^{-u}, u = sqrt(3) r;  e = e^{-u}
    RBF:        rho = e^{-r2/2};                    e = rho
    """
    if kind == "matern32":
        u = jnp.sqrt(3.0 * r2)
        e = jnp.exp(-u)
        return (1.0 + u) * e, e, u
    elif kind == "rbf":
        rho = jnp.exp(-0.5 * r2)
        return rho, rho, None
    raise ValueError(f"unknown kernel kind {kind!r}")


def _grad_weight(kind, e, u, r2):
    """Elementwise weight W s.t. d/dlog_l_i [K] v = (W .* d_i^2) @ v_scaled."""
    if kind == "matern32":
        return 3.0 * e
    # RBF: dk/dlog_l_i = k * d_i^2_scaled
    return e


# ---------------------------------------------------------------------------
# Pallas kernel bodies
# ---------------------------------------------------------------------------


def _mvm_kernel(xr_ref, xc_ref, v_ref, o_ref, *, kind):
    """Fused K@V accumulation over column blocks (grid axis 0)."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    r2 = _tile_r2(xr_ref[...], xc_ref[...])
    rho, _, _ = _rho_and_e(kind, r2)
    o_ref[...] += jnp.dot(rho, v_ref[...], preferred_element_type=jnp.float32)


def _mvm_grads_shared_kernel(xr_ref, xc_ref, v_ref, o_ref, g_ref, *, kind):
    """K@V and (d/dlog_l K)@V for a shared lengthscale, one fused pass."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    r2 = _tile_r2(xr_ref[...], xc_ref[...])
    rho, e, u = _rho_and_e(kind, r2)
    v = v_ref[...]
    o_ref[...] += jnp.dot(rho, v, preferred_element_type=jnp.float32)
    if kind == "matern32":
        w = e * (3.0 * r2)  # = e^{-u} u^2
    else:
        w = e * r2
    g_ref[...] += jnp.dot(w, v, preferred_element_type=jnp.float32)


def _mvm_grads_ard_kernel(xr_ref, xc_ref, v_ref, o_ref, g_ref, *, kind, d):
    """K@V and per-dimension (d/dlog_l_i K)@V, one fused pass.

    g_ref: (d, R, T). The per-dim squared-distance tiles reuse the same
    rank-1 expansion; the loop over d is static (unrolled at trace time).
    """
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    xr = xr_ref[...]
    xc = xc_ref[...]
    v = v_ref[...]
    r2 = _tile_r2(xr, xc)
    rho, e, u = _rho_and_e(kind, r2)
    o_ref[...] += jnp.dot(rho, v, preferred_element_type=jnp.float32)
    w = _grad_weight(kind, e, u, r2)
    for i in range(d):
        ri = xr[:, i : i + 1]  # (R, 1)
        ci = xc[:, i : i + 1].T  # (1, C)
        d2 = ri * ri + ci * ci - 2.0 * (ri * ci)
        g_ref[i, ...] += jnp.dot(w * d2, v, preferred_element_type=jnp.float32)


def _cross_kernel(xr_ref, xc_ref, o_ref, *, kind):
    """Explicit covariance tile K(xr, xc) (no matvec)."""
    r2 = _tile_r2(xr_ref[...], xc_ref[...])
    rho, _, _ = _rho_and_e(kind, r2)
    o_ref[...] = rho


# ---------------------------------------------------------------------------
# Scaling wrappers (fold hyperparameters into inputs) + pallas_call builders
# ---------------------------------------------------------------------------


def _scale_inputs(mode, d, xr, xc, v, theta):
    """Fold theta into the tensors; see module docstring."""
    if mode == "shared":
        inv_l = jnp.exp(-theta[0])
        os = jnp.exp(theta[1])
        return xr * inv_l, xc * inv_l, v * os
    inv_ls = jnp.exp(-theta[:d])[None, :]
    os = jnp.exp(theta[d])
    return xr * inv_ls, xc * inv_ls, v * os


def build_pallas_mvm(kind, mode, r, c, t, d, cb=None, interpret=True):
    """fn(xr (r,d), xc (c,d), v (c,t), theta) -> (K@v (r,t),)"""
    cb = cb or min(c, 512)
    assert c % cb == 0
    grid = (c // cb,)
    call = pl.pallas_call(
        functools.partial(_mvm_kernel, kind=kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, d), lambda j: (0, 0)),
            pl.BlockSpec((cb, d), lambda j: (j, 0)),
            pl.BlockSpec((cb, t), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((r, t), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, t), jnp.float32),
        interpret=interpret,
    )

    def fn(xr, xc, v, theta):
        xr_s, xc_s, v_s = _scale_inputs(mode, d, xr, xc, v, theta)
        return (call(xr_s, xc_s, v_s),)

    return fn


def build_pallas_mvm_grads(kind, mode, r, c, t, d, cb=None, interpret=True):
    """fn(xr, xc, v, theta) -> (K@v (r,t), G (nl,r,t)) with nl = 1|d."""
    cb = cb or min(c, 512)
    assert c % cb == 0
    grid = (c // cb,)
    if mode == "shared":
        body = functools.partial(_mvm_grads_shared_kernel, kind=kind)
        g_shape, g_spec = (r, t), pl.BlockSpec((r, t), lambda j: (0, 0))
    else:
        body = functools.partial(_mvm_grads_ard_kernel, kind=kind, d=d)
        g_shape = (d, r, t)
        g_spec = pl.BlockSpec((d, r, t), lambda j: (0, 0, 0))
    call = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, d), lambda j: (0, 0)),
            pl.BlockSpec((cb, d), lambda j: (j, 0)),
            pl.BlockSpec((cb, t), lambda j: (j, 0)),
        ],
        out_specs=[pl.BlockSpec((r, t), lambda j: (0, 0)), g_spec],
        out_shape=[
            jax.ShapeDtypeStruct((r, t), jnp.float32),
            jax.ShapeDtypeStruct(g_shape, jnp.float32),
        ],
        interpret=interpret,
    )

    def fn(xr, xc, v, theta):
        xr_s, xc_s, v_s = _scale_inputs(mode, d, xr, xc, v, theta)
        kv, g = call(xr_s, xc_s, v_s)
        if mode == "shared":
            g = g[None, ...]
        return (kv, g)

    return fn


def build_pallas_cross(kind, mode, r, c, d, cb=None, interpret=True):
    """fn(xr, xc, theta) -> (K(xr, xc) (r, c),) — explicit covariance tile."""
    cb = cb or min(c, 512)
    assert c % cb == 0
    grid = (c // cb,)
    call = pl.pallas_call(
        functools.partial(_cross_kernel, kind=kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, d), lambda j: (0, 0)),
            pl.BlockSpec((cb, d), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((r, cb), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=interpret,
    )

    def fn(xr, xc, theta):
        # outputscale folded back in at the end (no V to fold it into).
        if mode == "shared":
            inv_l = jnp.exp(-theta[0])
            os = jnp.exp(theta[1])
            xr_s, xc_s = xr * inv_l, xc * inv_l
        else:
            inv_ls = jnp.exp(-theta[:d])[None, :]
            os = jnp.exp(theta[d])
            xr_s, xc_s = xr * inv_ls, xc * inv_ls
        return (os * call(xr_s, xc_s),)

    return fn
