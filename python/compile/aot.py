"""AOT lowering: jax entry points -> HLO text artifacts + manifest.json.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the `xla` rust crate) rejects; the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Python runs ONCE, here. The Rust coordinator loads `manifest.json`, picks
artifacts by (entry, kind, mode, flavor, shape), compiles them with the
PJRT CPU client at startup, and never calls back into Python.

Usage:  cd python && python -m compile.aot --out ../artifacts [--profile quick|full]
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, sgpr, svgp

F32 = jnp.float32


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Artifact plan
# ---------------------------------------------------------------------------

# Production tile geometry (DESIGN.md SS2): rows x cols per tile call.
TILE_R, TILE_C = 512, 2048
CROSS_R, CROSS_C = 512, 512
SVGP_B = 1024

NTHETA = {"shared": 2, "ard": lambda d: d + 1}  # kernel-only theta
NTHETA_FULL = {"shared": 3, "ard": lambda d: d + 2}  # + log_noise


def _ntheta(mode, d, full=False):
    tbl = NTHETA_FULL if full else NTHETA
    v = tbl[mode]
    return v if isinstance(v, int) else v(d)


def plan(profile):
    """Yield artifact descriptors: (name, build_fn, arg_specs, meta)."""
    arts = []

    def mvm_family(kind, mode, d, tees, flavors):
        p = _ntheta(mode, d)
        for flavor in flavors:
            for t in tees:
                name = f"mvm__{kind}_{mode}_{flavor}__r{TILE_R}c{TILE_C}t{t}d{d}"
                fn = model.build_mvm(flavor, kind, mode, TILE_R, TILE_C, t, d)
                args = [
                    spec(TILE_R, d),
                    spec(TILE_C, d),
                    spec(TILE_C, t),
                    spec(p),
                ]
                arts.append(
                    (name, fn, args,
                     dict(entry="mvm", kind=kind, mode=mode, flavor=flavor,
                          r=TILE_R, c=TILE_C, t=t, d=d, outputs=1))
                )
            # gradient tile (largest t only)
            t = max(tees)
            name = f"mvmgrad__{kind}_{mode}_{flavor}__r{TILE_R}c{TILE_C}t{t}d{d}"
            fn = model.build_mvm_grads(flavor, kind, mode, TILE_R, TILE_C, t, d)
            args = [spec(TILE_R, d), spec(TILE_C, d), spec(TILE_C, t), spec(p)]
            arts.append(
                (name, fn, args,
                 dict(entry="mvmgrad", kind=kind, mode=mode, flavor=flavor,
                      r=TILE_R, c=TILE_C, t=t, d=d, outputs=2))
            )

    def cross_family(kind, mode, d, flavors):
        p = _ntheta(mode, d)
        for flavor in flavors:
            name = f"cross__{kind}_{mode}_{flavor}__r{CROSS_R}c{CROSS_C}d{d}"
            fn = model.build_cross(flavor, kind, mode, CROSS_R, CROSS_C, d)
            args = [spec(CROSS_R, d), spec(CROSS_C, d), spec(p)]
            arts.append(
                (name, fn, args,
                 dict(entry="cross", kind=kind, mode=mode, flavor=flavor,
                      r=CROSS_R, c=CROSS_C, d=d, outputs=1))
            )

    def svgp_family(kind, mode, d, ms):
        p = _ntheta(mode, d, full=True)
        for m in ms:
            name = f"svgp__{kind}_{mode}_jnp__m{m}b{SVGP_B}d{d}"
            fn = svgp.build_svgp_step(kind, mode, m, SVGP_B, d)
            args = [
                spec(m, d), spec(m), spec(m, m), spec(p),
                spec(SVGP_B, d), spec(SVGP_B), spec(),
            ]
            arts.append(
                (name, fn, args,
                 dict(entry="svgp", kind=kind, mode=mode, flavor="jnp",
                      m=m, b=SVGP_B, d=d, outputs=5))
            )

    def sgpr_family(kind, mode, d, m_n_pairs):
        p = _ntheta(mode, d, full=True)
        for m, n in m_n_pairs:
            name = f"sgpr__{kind}_{mode}_jnp__m{m}n{n}d{d}"
            fn = sgpr.build_sgpr_step(kind, mode, m, n, d)
            args = [spec(m, d), spec(p), spec(n, d), spec(n), spec(n)]
            arts.append(
                (name, fn, args,
                 dict(entry="sgpr", kind=kind, mode=mode, flavor="jnp",
                      m=m, n=n, d=d, outputs=3))
            )

    if profile == "quick":
        # Minimal set: enough for rust integration tests.
        mvm_family("matern32", "shared", 32, [1, 16], ["jnp", "pallas"])
        cross_family("matern32", "shared", 32, ["jnp"])
        svgp_family("matern32", "shared", 32, [64])
        sgpr_family("matern32", "shared", 32, [(64, 4096)])
        return arts

    flavors = ["jnp", "pallas"]
    for mode in ("shared", "ard"):
        mvm_family("matern32", mode, 32, [1, 16], flavors)
        cross_family("matern32", mode, 32, flavors)
    mvm_family("matern32", "shared", 8, [1, 16], flavors)
    mvm_family("rbf", "shared", 32, [1, 16], flavors)

    svgp_family("matern32", "shared", 32, [16, 64, 256, 1024])
    svgp_family("matern32", "ard", 32, [64, 256])
    sgpr_family(
        "matern32", "shared", 32,
        [(16, 4096), (64, 4096), (128, 4096), (256, 4096), (512, 4096),
         (64, 16384), (128, 16384), (512, 16384)],
    )
    sgpr_family("matern32", "ard", 32, [(64, 4096), (128, 4096), (128, 16384)])
    return arts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--profile",
                    default=os.environ.get("EXACTGP_AOT_PROFILE", "full"),
                    choices=["quick", "full"])
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"version": 1, "profile": args.profile, "tile": {
        "r": TILE_R, "c": TILE_C, "cross_r": CROSS_R, "cross_c": CROSS_C,
        "svgp_b": SVGP_B,
    }, "artifacts": []}

    arts = plan(args.profile)
    t0 = time.time()
    for i, (name, fn, argspecs, meta) in enumerate(arts):
        path = f"{name}.hlo.txt"
        full = os.path.join(args.out, path)
        t1 = time.time()
        lowered = jax.jit(fn).lower(*argspecs)
        text = to_hlo_text(lowered)
        with open(full, "w") as f:
            f.write(text)
        meta = dict(meta)
        meta["name"] = name
        meta["file"] = path
        meta["inputs"] = [list(s.shape) for s in argspecs]
        manifest["artifacts"].append(meta)
        print(f"[{i+1}/{len(arts)}] {name}  ({time.time()-t1:.1f}s, "
              f"{len(text)//1024} KiB)", file=sys.stderr)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(arts)} artifacts in {time.time()-t0:.1f}s "
          f"-> {args.out}/manifest.json", file=sys.stderr)


if __name__ == "__main__":
    main()
