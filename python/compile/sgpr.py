"""L2: SGPR (Titsias 2009) collapsed variational bound + gradients.

The paper's first baseline: sparse GP regression with m = 512 inducing
points learned by maximizing the collapsed bound with Adam. Full-batch
objective; fixed-shape artifacts are compiled for a menu of padded N
(rows beyond the true n carry mask = 0 and contribute nothing).

Bound (Gaussian likelihood, Q = Kxz Kzz^{-1} Kzx):

    log p(y) >= -1/2 [ N log 2pi + log|Q + s2 I| + y^T (Q + s2 I)^{-1} y ]
                - 1/(2 s2) tr(K - Q)

computed via the standard Woodbury factorization with
A = Lz^{-1} Kzx / s,  B = I + A A^T.
"""

import jax
import jax.numpy as jnp
from .linalg_jax import cholesky as _chol, solve_lower as _slo, solve_upper as _sup

from .model import _r2, _rho
from .svgp import JITTER, LOG2PI, _kernel_parts, _kmat


def neg_bound(kind, mode, z, theta, x, y, mask):
    """Negative collapsed bound, masked rows excluded."""
    m, d = z.shape
    inv, os, s2 = _kernel_parts(kind, mode, d, theta)

    z_s = z * inv
    x_s = x * inv
    kzz = _kmat(kind, z_s, z_s, os) + JITTER * jnp.eye(m)
    kzx = _kmat(kind, z_s, x_s, os) * mask[None, :]  # (M, N), masked cols
    y_m = y * mask
    n_eff = jnp.sum(mask)

    lz = _chol(kzz)
    a = _slo(lz, kzx) / jnp.sqrt(s2)  # (M, N)
    b = jnp.eye(m) + a @ a.T
    lb = _chol(b)
    ay = a @ y_m
    c = _slo(lb, ay) / jnp.sqrt(s2)

    logdet = n_eff * jnp.log(s2) + 2.0 * jnp.sum(jnp.log(jnp.diag(lb)))
    quad = jnp.dot(y_m, y_m) / s2 - jnp.dot(c, c)
    # tr(K - Q) over unmasked rows; K_ii = os (stationary kernel).
    trace = (os * n_eff - s2 * jnp.sum(a * a)) / s2

    return 0.5 * (n_eff * LOG2PI + logdet + quad) + 0.5 * trace


def build_sgpr_step(kind, mode, m, n, d):
    """fn(z, theta, x (n,d), y (n,), mask (n,)) -> (loss, g_z, g_theta)."""
    grad = jax.grad(
        lambda z, theta, x, y, mask: neg_bound(kind, mode, z, theta, x, y, mask),
        argnums=(0, 1),
    )

    def fn(z, theta, x, y, mask):
        loss = neg_bound(kind, mode, z, theta, x, y, mask)
        gz, gth = grad(z, theta, x, y, mask)
        return (loss, gz, gth)

    return fn


def sgpr_predict_ref(kind, mode, z, theta, x, y, xstar):
    """Oracle for the Rust-native SGPR predictor (tests only).

    mu* = Ksz Lz^{-T} Lb^{-T} c      var* = k** - ||Lz^{-1} kz*||^2
                                            + ||Lb^{-1} Lz^{-1} kz*||^2
    """
    m, d = z.shape
    inv, os, s2 = _kernel_parts(kind, mode, d, theta)
    z_s, x_s, xs_s = z * inv, x * inv, xstar * inv
    kzz = _kmat(kind, z_s, z_s, os) + JITTER * jnp.eye(m)
    kzx = _kmat(kind, z_s, x_s, os)
    kzs = _kmat(kind, z_s, xs_s, os)
    lz = _chol(kzz)
    a = _slo(lz, kzx) / jnp.sqrt(s2)
    b = jnp.eye(m) + a @ a.T
    lb = _chol(b)
    c = _slo(lb, a @ y) / jnp.sqrt(s2)

    proj = _slo(lz, kzs)  # (M, S)
    proj_b = _slo(lb, proj)
    mean = proj_b.T @ c
    var = jnp.maximum(
        os - jnp.sum(proj * proj, axis=0) + jnp.sum(proj_b * proj_b, axis=0),
        0.0,
    )
    return mean, var
